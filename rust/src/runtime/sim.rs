//! `SimEngine` — the pure-Rust execution backend.
//!
//! Natively executes the tiny-model stage functions with the exact semantics
//! of `python/compile/model.py` + `python/compile/kernels/ref.py` (RMSNorm,
//! RoPE, GQA attention under the APB modified mask, SwiGLU FFN, gelu
//! retaining-head MLP), on `util::tensor` dense f32 tensors with f64
//! accumulation. No Python, no XLA, no artifacts: weights are synthesized
//! deterministically from `util::rng::Rng` keyed on `Config::seed`.
//!
//! Two structural properties of *trained* models are imposed on the
//! synthetic weights (mirroring `model.init_params` — DESIGN.md §2):
//!
//! * query/key projections are aligned per GQA group
//!   (`wq[:, head] = wk[:, kv_head] + 0.5·noise`), so `q·k` is elevated when
//!   token i matches token j — without this no retrieval mechanism exists
//!   and every retention experiment is void;
//! * the retaining heads are the sim stand-in for the *trained* compressor
//!   (`train_retaining.py` on the python side): the gelu MLP is wired to
//!   read the query-similarity feature of `build_features`, so
//!   query-relevant KV units score high, exactly what training produces.

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{bail, Result};

use crate::config::{BackendKind, Config, ModelConfig};
use crate::util::rng::Rng;
use crate::util::tensor::Tensor;

use super::pool::{ShardedOut, SimPool};
use super::ExecBackend;

// ---------------------------------------------------------------------------
// Math primitives (pub: reused by the numerics test suite and benches)
// ---------------------------------------------------------------------------

/// Dense matmul `[n, a] x [a, b] -> [n, b]` with f64 accumulation.
pub fn matmul(x: &Tensor, w: &Tensor) -> Tensor {
    assert_eq!(x.rank(), 2);
    assert_eq!(w.rank(), 2);
    let (n, a) = (x.shape[0], x.shape[1]);
    let (aw, b) = (w.shape[0], w.shape[1]);
    assert_eq!(a, aw, "matmul inner dims {a} vs {aw}");
    let mut out = Tensor::zeros(vec![n, b]);
    let mut acc = vec![0f64; b];
    for i in 0..n {
        for slot in acc.iter_mut() {
            *slot = 0.0;
        }
        for t in 0..a {
            let xv = x.data[i * a + t] as f64;
            if xv == 0.0 {
                continue;
            }
            let wrow = &w.data[t * b..(t + 1) * b];
            for (slot, &wv) in acc.iter_mut().zip(wrow) {
                *slot += xv * wv as f64;
            }
        }
        for (o, &slot) in out.data[i * b..(i + 1) * b].iter_mut().zip(&acc) {
            *o = slot as f32;
        }
    }
    out
}

/// Row-wise RMSNorm: `x * rsqrt(mean(x^2) + eps) * w`, `w` broadcast per row.
pub fn rmsnorm(x: &Tensor, w: &[f32], eps: f64) -> Tensor {
    assert_eq!(x.rank(), 2);
    let (n, d) = (x.shape[0], x.shape[1]);
    assert_eq!(w.len(), d);
    let mut out = Tensor::zeros(vec![n, d]);
    for i in 0..n {
        let row = &x.data[i * d..(i + 1) * d];
        let var: f64 = row.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / d as f64;
        let scale = 1.0 / (var + eps).sqrt();
        for (o, (&xv, &wv)) in out.data[i * d..(i + 1) * d]
            .iter_mut()
            .zip(row.iter().zip(w))
        {
            *o = (xv as f64 * scale * wv as f64) as f32;
        }
    }
    out
}

/// Rotary embedding on `x [n, heads, hd]` at integer `positions [n]`
/// (half-split rotation, matching `model.rope`).
pub fn rope(x: &Tensor, positions: &[i32], theta: f64) -> Tensor {
    assert_eq!(x.rank(), 3);
    let (n, h, hd) = (x.shape[0], x.shape[1], x.shape[2]);
    assert_eq!(positions.len(), n);
    let half = hd / 2;
    let freqs: Vec<f64> = (0..half)
        .map(|t| theta.powf(-(t as f64) / half as f64))
        .collect();
    let mut out = x.clone();
    for i in 0..n {
        let pos = positions[i] as f64;
        for (t, &freq) in freqs.iter().enumerate() {
            let angle = pos * freq;
            let (sin, cos) = angle.sin_cos();
            for hh in 0..h {
                let base = (i * h + hh) * hd;
                let x1 = x.data[base + t] as f64;
                let x2 = x.data[base + half + t] as f64;
                out.data[base + t] = (x1 * cos - x2 * sin) as f32;
                out.data[base + half + t] = (x1 * sin + x2 * cos) as f32;
            }
        }
    }
    out
}

/// tanh-approximated gelu, matching `ref.retaining_head_ref`.
pub fn gelu(x: f64) -> f64 {
    let c = (2.0 / std::f64::consts::PI).sqrt();
    0.5 * x * (1.0 + (c * (x + 0.044715 * x * x * x)).tanh())
}

fn silu(x: f64) -> f64 {
    x / (1.0 + (-x).exp())
}

/// Dense masked GQA attention, the rust twin of `ref.attention_ref`:
/// `q [nq, h, hd]`, `k`/`v` `[nk, kh, hd]`, query head `i` reads kv head
/// `i / (h/kh)`. `visible(qi, kj)` is the boolean mask. Returns
/// `(out [nq, h, hd], lse [nq, h])`; rows with no visible keys get output 0
/// and lse `-inf` (the convention the online-softmax merge relies on).
///
/// This IS [`masked_attention_seg`] over a single segment spanning every
/// row of `k`/`v` — one kernel, two entry points, so attending a
/// `[shared | private]` prefix-cache view is bit-identical to attending the
/// contiguous cache it replaces (the invariant
/// `docs/ADR-003-prefix-caching.md` rests on).
pub fn masked_attention<F: Fn(usize, usize) -> bool + Sync>(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    visible: F,
) -> (Tensor, Tensor) {
    let seg = super::KvSeg { k, v, len: k.shape[0] };
    masked_attention_seg(q, &[seg], visible)
}

/// Segmented masked GQA attention: the logical key/value sequence is the
/// in-order concatenation of `segs` (each contributing its first `len`
/// rows), attended WITHOUT materializing the concatenation — the kernel the
/// prefix cache's `[shared | private]` KV views decode through.
///
/// `visible(qi, kj)` masks over the *logical* key index `kj` (0-based
/// across segments in order). The per-(row, head) f64 accumulation walks
/// keys in logical order, so for equal row values the result is
/// bit-identical to [`masked_attention`] over the contiguous equivalent.
///
/// This entry point runs the tiled kernel serially on the calling thread;
/// `SimEngine` routes through the same work units on its [`SimPool`].
/// Either way the result is bit-identical to [`masked_attention_seg_ref`],
/// the retired scalar loop kept as the oracle (`docs/ADR-005-sim-perf.md`
/// spells out the ordering argument).
pub fn masked_attention_seg<F: Fn(usize, usize) -> bool + Sync>(
    q: &Tensor,
    segs: &[super::KvSeg<'_>],
    visible: F,
) -> (Tensor, Tensor) {
    seg_attn_dispatch(None, q, segs, &visible)
}

/// Key-tile width of the blocked attention passes: a tile of visible keys
/// is processed against every head of a unit's GQA group before the next
/// tile is touched, so the tile's K/V rows are reused from cache `g` times
/// instead of re-streamed per head. 32 keys × 32 dims × 4 B = 4 KiB per
/// tile per tensor — L1-resident alongside the q rows and scratch.
const KEY_TILE: usize = 32;

/// Per-thread kernel scratch, reused across calls — hoists the per-call
/// heap allocations of the scalar reference (`vis`/`scores`/`acc` vectors
/// and the multi-segment locate map) out of the hot path.
#[derive(Default)]
struct AttnScratch {
    /// Visible logical key indices of one query row.
    vis: Vec<u32>,
    /// `(dispatch nonce, absolute query row)` that `vis` is valid for.
    /// Scratch persists across calls on each thread, so reuse must be keyed:
    /// consecutive dispatches may ask different masks for the same row.
    vis_key: (u64, u64),
    /// Scores of a unit's `g` heads over the visible keys, head-major.
    scores: Vec<f64>,
    /// Running score max per head of the unit.
    maxes: Vec<f64>,
    /// Softmax denominator per head of the unit.
    denoms: Vec<f64>,
    /// f64 value accumulators, `g * hd`, head-major.
    acc: Vec<f64>,
    /// One finished f32 output row, staged before the sharded write.
    out_row: Vec<f32>,
}

thread_local! {
    static ATTN_SCRATCH: RefCell<AttnScratch> = RefCell::new(AttnScratch::default());
    /// Logical key -> (segment, local row) map of a multi-segment dispatch.
    /// Deliberately a SEPARATE cell from `ATTN_SCRATCH`: the dispatcher
    /// holds this borrow across the whole job while every work unit —
    /// including the ones the dispatching thread itself executes — takes
    /// `ATTN_SCRATCH` mutably.
    static SEG_MAP: RefCell<Vec<(u32, u32)>> = const { RefCell::new(Vec::new()) };
    /// Feature-vector scratch of the pooled retaining-head scorer.
    static FEAT_SCRATCH: RefCell<Vec<f64>> = const { RefCell::new(Vec::new()) };
}

/// Distinguishes dispatches in `AttnScratch::vis_key`. Starts at 1 so the
/// default key `(0, 0)` can never collide with a live dispatch.
static DISPATCH_NONCE: AtomicU64 = AtomicU64::new(1);

/// One dispatch's loop-invariant state, shared read-only by every work unit.
struct SegAttn<'a> {
    q: &'a Tensor,
    segs: &'a [super::KvSeg<'a>],
    /// Multi-segment locate map (empty when `single`).
    map: &'a [(u32, u32)],
    single: bool,
    nk: usize,
    h: usize,
    kh: usize,
    g: usize,
    hd: usize,
    scale: f64,
    nonce: u64,
    /// Absolute `q`/output row under local row 0 — the batched-decode path
    /// points one unit at one absolute row; full dispatches use 0.
    row0: usize,
}

impl SegAttn<'_> {
    #[inline(always)]
    fn locate(&self, kj: usize) -> (usize, usize) {
        if self.single {
            (0, kj)
        } else {
            let (si, r) = self.map[kj];
            (si as usize, r as usize)
        }
    }

    /// Compute heads `j*g .. (j+1)*g` of local query row `i` — one
    /// (query-row × kv-head) work unit. Keys are walked in logical order in
    /// `KEY_TILE` blocks with the group's heads innermost: per (row, head)
    /// every f64 operation happens in exactly the scalar reference's order,
    /// so the result is bit-identical — tiling only changes which head
    /// visits a key tile next, never the order of any head's accumulation.
    fn unit<F: Fn(usize, usize) -> bool>(
        &self,
        visible: &F,
        i: usize,
        j: usize,
        out: &ShardedOut<'_>,
        lse: &ShardedOut<'_>,
    ) {
        let (h, kh, g, hd) = (self.h, self.kh, self.g, self.hd);
        let row = self.row0 + i;
        ATTN_SCRATCH.with(|cell| {
            let mut scratch = cell.borrow_mut();
            let AttnScratch { vis, vis_key, scores, maxes, denoms, acc, out_row } =
                &mut *scratch;
            let key = (self.nonce, row as u64);
            if *vis_key != key {
                vis.clear();
                vis.extend((0..self.nk).filter(|&kj| visible(i, kj)).map(|kj| kj as u32));
                *vis_key = key;
            }
            if vis.is_empty() {
                for hh in j * g..(j + 1) * g {
                    lse.set(row * h + hh, f32::NEG_INFINITY);
                }
                return; // output rows stay zero
            }
            let nv = vis.len();
            scores.clear();
            scores.resize(g * nv, 0.0);
            maxes.clear();
            maxes.resize(g, f64::NEG_INFINITY);
            // Pass 1: scores + running max. Key tiles outer, heads inner.
            let mut t0 = 0usize;
            for tile in vis.chunks(KEY_TILE) {
                for hl in 0..g {
                    let qb = (row * h + j * g + hl) * hd;
                    let qrow = &self.q.data[qb..qb + hd];
                    let mut m = maxes[hl];
                    for (ti, &kj) in tile.iter().enumerate() {
                        let (si, r) = self.locate(kj as usize);
                        let kb = (r * kh + j) * hd;
                        let kd = &self.segs[si].k.data[kb..kb + hd];
                        let mut dot = 0f64;
                        for d in 0..hd {
                            dot += qrow[d] as f64 * kd[d] as f64;
                        }
                        let s = dot * self.scale;
                        scores[hl * nv + t0 + ti] = s;
                        m = m.max(s);
                    }
                    maxes[hl] = m;
                }
                t0 += tile.len();
            }
            // Pass 2: softmax accumulation, same tile-outer/head-inner walk,
            // per head strictly in logical key order.
            denoms.clear();
            denoms.resize(g, 0.0);
            acc.clear();
            acc.resize(g * hd, 0.0);
            let mut t0 = 0usize;
            for tile in vis.chunks(KEY_TILE) {
                for hl in 0..g {
                    let m = maxes[hl];
                    let arow = &mut acc[hl * hd..(hl + 1) * hd];
                    let mut denom = denoms[hl];
                    for (ti, &kj) in tile.iter().enumerate() {
                        let w = (scores[hl * nv + t0 + ti] - m).exp();
                        denom += w;
                        let (si, r) = self.locate(kj as usize);
                        let vb = (r * kh + j) * hd;
                        let vd = &self.segs[si].v.data[vb..vb + hd];
                        for (slot, &vv) in arow.iter_mut().zip(vd) {
                            *slot += w * vv as f64;
                        }
                    }
                    denoms[hl] = denom;
                }
                t0 += tile.len();
            }
            out_row.clear();
            out_row.resize(hd, 0.0);
            for hl in 0..g {
                let hh = j * g + hl;
                let denom = denoms[hl];
                for (o, &slot) in out_row.iter_mut().zip(&acc[hl * hd..(hl + 1) * hd]) {
                    *o = (slot / denom) as f32;
                }
                out.write((row * h + hh) * hd, out_row);
                lse.set(row * h + hh, (maxes[hl] + denom.ln()) as f32);
            }
        });
    }
}

/// Shared dispatcher behind [`masked_attention_seg`] and the engine's
/// pooled attention: validates shapes, builds the segment map once into
/// per-thread scratch, and drains the `(query-row × kv-head)` units either
/// inline (`pool: None`) or across the pool.
fn seg_attn_dispatch<F: Fn(usize, usize) -> bool + Sync>(
    pool: Option<&SimPool>,
    q: &Tensor,
    segs: &[super::KvSeg<'_>],
    visible: &F,
) -> (Tensor, Tensor) {
    assert_eq!(q.rank(), 3);
    let (nq, h, hd) = (q.shape[0], q.shape[1], q.shape[2]);
    let kh = segs.first().map_or(1, |s| s.k.shape[1]);
    for s in segs {
        assert_eq!(s.k.rank(), 3);
        assert_eq!(s.k.shape, s.v.shape);
        assert!(s.len <= s.k.shape[0], "segment len {} > rows {}", s.len, s.k.shape[0]);
        assert_eq!(s.k.shape[1], kh, "segments disagree on kv heads");
        assert_eq!(s.k.shape[2], hd, "segments disagree on head dim");
    }
    assert_eq!(h % kh, 0, "GQA heads {h} not divisible by kv heads {kh}");
    let g = h / kh;
    let single = segs.len() == 1;
    let mut out = Tensor::zeros(vec![nq, h, hd]);
    let mut lse = Tensor::zeros(vec![nq, h]);
    SEG_MAP.with(|cell| {
        let mut map = cell.borrow_mut();
        map.clear();
        if !single {
            for (si, s) in segs.iter().enumerate() {
                map.extend((0..s.len).map(|r| (si as u32, r as u32)));
            }
        }
        let nk = if single { segs[0].len } else { map.len() };
        let ctx = SegAttn {
            q,
            segs,
            map: &map,
            single,
            nk,
            h,
            kh,
            g,
            hd,
            scale: 1.0 / (hd as f64).sqrt(),
            nonce: DISPATCH_NONCE.fetch_add(1, Ordering::Relaxed),
            row0: 0,
        };
        let out_sh = ShardedOut::new(&mut out.data);
        let lse_sh = ShardedOut::new(&mut lse.data);
        let work = |u: usize| ctx.unit(visible, u / kh, u % kh, &out_sh, &lse_sh);
        match pool {
            Some(p) => p.run(nq * kh, &work),
            None => {
                for u in 0..nq * kh {
                    work(u);
                }
            }
        }
    });
    (out, lse)
}

/// The retired scalar loop, kept verbatim as the bit-identity oracle for
/// the tiled kernel (and as the baseline the benches compare against via
/// `Config::sim_scalar`). See [`masked_attention_seg`] for semantics.
pub fn masked_attention_seg_ref<F: Fn(usize, usize) -> bool>(
    q: &Tensor,
    segs: &[super::KvSeg<'_>],
    visible: F,
) -> (Tensor, Tensor) {
    assert_eq!(q.rank(), 3);
    let (nq, h, hd) = (q.shape[0], q.shape[1], q.shape[2]);
    let kh = segs.first().map_or(1, |s| s.k.shape[1]);
    for s in segs {
        assert_eq!(s.k.rank(), 3);
        assert_eq!(s.k.shape, s.v.shape);
        assert!(s.len <= s.k.shape[0], "segment len {} > rows {}", s.len, s.k.shape[0]);
        assert_eq!(s.k.shape[1], kh, "segments disagree on kv heads");
        assert_eq!(s.k.shape[2], hd, "segments disagree on head dim");
    }
    // Logical key kj -> (segment, local row). The single-segment case is
    // the identity map, kept allocation- and indirection-free (the mapping
    // never changes values, only where a row is fetched from).
    let single = segs.len() == 1;
    let mut src: Vec<(usize, usize)> = Vec::new();
    if !single {
        for (si, s) in segs.iter().enumerate() {
            src.extend((0..s.len).map(|r| (si, r)));
        }
    }
    let nk = if single { segs[0].len } else { src.len() };
    let locate = |kj: usize| -> (usize, usize) {
        if single { (0, kj) } else { src[kj] }
    };
    assert_eq!(h % kh, 0, "GQA heads {h} not divisible by kv heads {kh}");
    let g = h / kh;
    let scale = 1.0 / (hd as f64).sqrt();
    let mut out = Tensor::zeros(vec![nq, h, hd]);
    let mut lse = Tensor::zeros(vec![nq, h]);
    let mut vis_idx: Vec<usize> = Vec::with_capacity(nk);
    let mut scores = vec![0f64; nk];
    let mut acc = vec![0f64; hd];
    for i in 0..nq {
        // The mask depends only on (qi, kj): evaluate it once per row and
        // iterate the visible-key list per head, so padded cache rows and
        // masked keys cost nothing in the inner loops.
        vis_idx.clear();
        vis_idx.extend((0..nk).filter(|&kj| visible(i, kj)));
        for hh in 0..h {
            let j = hh / g;
            let qb = (i * h + hh) * hd;
            if vis_idx.is_empty() {
                lse.data[i * h + hh] = f32::NEG_INFINITY;
                continue; // output row stays zero
            }
            let mut m = f64::NEG_INFINITY;
            for &kj in &vis_idx {
                let (si, r) = locate(kj);
                let kb = (r * kh + j) * hd;
                let kd = &segs[si].k.data;
                let mut dot = 0f64;
                for d in 0..hd {
                    dot += q.data[qb + d] as f64 * kd[kb + d] as f64;
                }
                let s = dot * scale;
                scores[kj] = s;
                m = m.max(s);
            }
            for slot in acc.iter_mut() {
                *slot = 0.0;
            }
            let mut denom = 0f64;
            for &kj in &vis_idx {
                let w = (scores[kj] - m).exp();
                denom += w;
                let (si, r) = locate(kj);
                let vb = (r * kh + j) * hd;
                for (slot, &vv) in acc.iter_mut().zip(&segs[si].v.data[vb..vb + hd]) {
                    *slot += w * vv as f64;
                }
            }
            for (o, &slot) in out.data[qb..qb + hd].iter_mut().zip(&acc) {
                *o = (slot / denom) as f32;
            }
            lse.data[i * h + hh] = (m + denom.ln()) as f32;
        }
    }
    (out, lse)
}

/// The APB prefill visibility rule (paper Eq. 2 / `ref.apb_mask`).
///
/// Queries are `[anchor (l_aq) | local]`, keys
/// `[anchor (l_aq) | passing (pass_max, padded) | local]`:
/// * anchor query `qi < l_aq`: causal within the anchor segment;
/// * local query: the valid anchor prefix (`kj < n_anchor`), the valid
///   passing prefix (`offset < pass_len`), and the local segment causally.
pub fn apb_visible(
    l_aq: usize,
    pass_max: usize,
    n_anchor: usize,
    pass_len: usize,
    qi: usize,
    kj: usize,
) -> bool {
    if qi < l_aq {
        kj < l_aq && kj <= qi
    } else if kj < l_aq {
        kj < n_anchor
    } else if kj < l_aq + pass_max {
        kj - l_aq < pass_len
    } else {
        kj - l_aq - pass_max <= qi - l_aq
    }
}

// ---------------------------------------------------------------------------
// Weights
// ---------------------------------------------------------------------------

struct LayerWeights {
    attn_norm: Vec<f32>,
    wq: Tensor,
    wk: Tensor,
    wv: Tensor,
    wo: Tensor,
    ffn_norm: Vec<f32>,
    w_gate: Tensor,
    w_up: Tensor,
    w_down: Tensor,
    rh_w1: Tensor,
    rh_b1: Vec<f32>,
    rh_w2: Tensor,
    rh_b2: f32,
}

fn normal_tensor(rng: &mut Rng, shape: Vec<usize>) -> Tensor {
    let fan_in = shape[0] as f64;
    let std = 1.0 / fan_in.sqrt();
    let n: usize = shape.iter().product();
    let data = (0..n).map(|_| (rng.normal() * std) as f32).collect();
    Tensor { shape, data }
}

/// Shift that keeps the crafted retaining-head gelu in its monotone region
/// for any plausible similarity feature value.
const RH_GELU_SHIFT: f32 = 3.0;

fn layer_weights(rng: &mut Rng, m: &ModelConfig) -> LayerWeights {
    let (d, hd, h, kh) = (m.d_model, m.head_dim(), m.n_heads, m.n_kv_heads);
    let g = m.gqa_groups();
    let attn_norm = vec![1.0f32; d];
    let mut wq = normal_tensor(rng, vec![d, h * hd]);
    let wk = normal_tensor(rng, vec![d, kh * hd]);
    let wv = normal_tensor(rng, vec![d, kh * hd]);
    let wo = normal_tensor(rng, vec![h * hd, d]);
    // Align W_q with W_k per GQA group (retrieval-capable init, see module
    // docs): wq[:, head i] = wk[:, i/g] + 0.5 * noise.
    for r in 0..d {
        for hh in 0..h {
            let kv = hh / g;
            for c in 0..hd {
                let qi = r * (h * hd) + hh * hd + c;
                let ki = r * (kh * hd) + kv * hd + c;
                wq.data[qi] = wk.data[ki] + 0.5 * wq.data[qi];
            }
        }
    }
    let ffn_norm = vec![1.0f32; d];
    let w_gate = normal_tensor(rng, vec![d, m.d_ff]);
    let w_up = normal_tensor(rng, vec![d, m.d_ff]);
    let w_down = normal_tensor(rng, vec![m.d_ff, d]);
    // Crafted "trained" retaining head: hidden unit 0 reads the sim_max
    // feature (index 3*hd of build_features) shifted into gelu's monotone
    // region, and the output reads hidden unit 0 — so scores order KV units
    // by their query similarity, which is what the trained compressor does.
    let r = m.retaining_hidden;
    let mut rh_w1 = Tensor::zeros(vec![3 * hd + 2, r]);
    rh_w1.data[3 * hd * r] = 1.0; // feat[3*hd] (sim_max) -> hidden 0
    let mut rh_b1 = vec![0.0f32; r];
    rh_b1[0] = RH_GELU_SHIFT;
    let mut rh_w2 = Tensor::zeros(vec![r, 1]);
    rh_w2.data[0] = 1.0; // hidden 0 -> score
    LayerWeights {
        attn_norm,
        wq,
        wk,
        wv,
        wo,
        ffn_norm,
        w_gate,
        w_up,
        w_down,
        rh_w1,
        rh_b1,
        rh_w2,
        rh_b2: 0.0,
    }
}

// ---------------------------------------------------------------------------
// Engine
// ---------------------------------------------------------------------------

/// Pure-Rust per-host engine with deterministic synthetic weights. All hosts
/// construct identical weights from `Config::seed` (the model is replicated,
/// exactly like the PJRT path uploading one `weights.bin` everywhere).
pub struct SimEngine {
    model: ModelConfig,
    l_aq: usize,
    block_len: usize,
    query_len: usize,
    pass_max: usize,
    embed: Tensor,
    final_norm: Vec<f32>,
    lm_head_w: Tensor,
    layers: Vec<LayerWeights>,
    /// Row-parallel kernel pool, shared by every attention/scoring call of
    /// this engine (`docs/ADR-005-sim-perf.md`). Sized by
    /// [`resolve_sim_threads`] at construction.
    pool: SimPool,
    /// `Config::sim_scalar`: pin the retired scalar reference kernels (and
    /// a serial pool) — the baseline the runtime bench compares against.
    scalar: bool,
}

/// Resolve the engine's kernel-pool size: an explicit `Config::sim_threads`
/// wins; else the `APB_SIM_THREADS` env var; else
/// `available_parallelism / n_hosts` (so `Driver::Threaded` running one
/// engine per host thread keeps total threads ≈ core count), min 1.
///
/// Read once at engine construction — tests that need a specific size set
/// `Config::sim_threads` instead of racing on the process environment.
pub fn resolve_sim_threads(configured: usize, n_hosts: usize) -> usize {
    if configured > 0 {
        return configured;
    }
    if let Ok(s) = std::env::var("APB_SIM_THREADS") {
        if let Ok(n) = s.trim().parse::<usize>() {
            if n > 0 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map_or(1, |p| (p.get() / n_hosts.max(1)).max(1))
}

impl SimEngine {
    pub fn new(cfg: &Config) -> Result<SimEngine> {
        let m = &cfg.model;
        if m.d_model % m.n_heads != 0 || m.n_heads % m.n_kv_heads != 0 {
            bail!(
                "sim config '{}': d_model {} / heads {} / kv heads {} not divisible",
                cfg.name,
                m.d_model,
                m.n_heads,
                m.n_kv_heads
            );
        }
        if m.head_dim() % 2 != 0 {
            bail!("sim config '{}': head_dim {} must be even for RoPE", cfg.name, m.head_dim());
        }
        // One deterministic stream, identical traversal order on every host.
        let mut rng = Rng::new(cfg.seed ^ 0xA9B_0C0DE);
        let embed = normal_tensor(&mut rng, vec![m.vocab_size, m.d_model]);
        let final_norm = vec![1.0f32; m.d_model];
        let lm_head_w = normal_tensor(&mut rng, vec![m.d_model, m.vocab_size]);
        let layers = (0..m.n_layers).map(|_| layer_weights(&mut rng, m)).collect();
        let threads = if cfg.sim_scalar {
            1
        } else {
            resolve_sim_threads(cfg.sim_threads, cfg.apb.n_hosts)
        };
        Ok(SimEngine {
            model: m.clone(),
            l_aq: cfg.apb.l_aq(),
            block_len: cfg.apb.block_len,
            query_len: cfg.apb.query_len,
            pass_max: cfg.apb.pass_max(),
            embed,
            final_norm,
            lm_head_w,
            layers,
            pool: SimPool::new(threads),
            scalar: cfg.sim_scalar,
        })
    }

    /// Segmented attention through the engine's kernel selection: the tiled
    /// kernel drained across the engine pool, or the scalar reference when
    /// `Config::sim_scalar` pins the baseline. Bit-identical either way.
    fn attn<F: Fn(usize, usize) -> bool + Sync>(
        &self,
        q: &Tensor,
        segs: &[super::KvSeg<'_>],
        visible: F,
    ) -> (Tensor, Tensor) {
        if self.scalar {
            masked_attention_seg_ref(q, segs, visible)
        } else {
            seg_attn_dispatch(Some(&self.pool), q, segs, &visible)
        }
    }

    fn project_qkv(&self, lw: &LayerWeights, hidden: &Tensor) -> (Tensor, Tensor, Tensor) {
        let m = &self.model;
        let hd = m.head_dim();
        let n = hidden.shape[0];
        let x = rmsnorm(hidden, &lw.attn_norm, m.rms_eps);
        let q = matmul(&x, &lw.wq).reshape(vec![n, m.n_heads, hd]);
        let k = matmul(&x, &lw.wk).reshape(vec![n, m.n_kv_heads, hd]);
        let v = matmul(&x, &lw.wv).reshape(vec![n, m.n_kv_heads, hd]);
        (q, k, v)
    }

    /// O-proj + residual + SwiGLU FFN (shared tail of layer_post and
    /// decode_post). `att` is `[n, h, hd]`.
    fn attn_tail(&self, lw: &LayerWeights, hidden: &Tensor, att: &Tensor) -> Tensor {
        let m = &self.model;
        let n = hidden.shape[0];
        let att2 = att.clone().reshape(vec![n, m.n_heads * m.head_dim()]);
        let proj = matmul(&att2, &lw.wo);
        let mut h = hidden.clone();
        for (a, &b) in h.data.iter_mut().zip(&proj.data) {
            *a += b;
        }
        let x = rmsnorm(&h, &lw.ffn_norm, m.rms_eps);
        let gate = matmul(&x, &lw.w_gate);
        let up = matmul(&x, &lw.w_up);
        let mut act = Tensor::zeros(vec![n, m.d_ff]);
        for (o, (&gv, &uv)) in act.data.iter_mut().zip(gate.data.iter().zip(&up.data)) {
            *o = (silu(gv as f64) * uv as f64) as f32;
        }
        let down = matmul(&act, &lw.w_down);
        for (a, &b) in h.data.iter_mut().zip(&down.data) {
            *a += b;
        }
        h
    }

    /// Group-mean (over each GQA group) of the embedded-query rows'
    /// pre-RoPE Q — the compressor feature every local row's score shares
    /// (`kernels.build_features`). `q_nr_query` holds exactly the query
    /// rows; returns `[w * kh * hd]` flattened.
    fn query_mean(&self, q_nr_query: &Tensor) -> Vec<f64> {
        let m = &self.model;
        let (hd, kh, g) = (m.head_dim(), m.n_kv_heads, m.gqa_groups());
        let w = q_nr_query.shape[0];
        let mut qq = vec![0f64; w * kh * hd];
        for wi in 0..w {
            for j in 0..kh {
                for d in 0..hd {
                    let mut s = 0f64;
                    for t in 0..g {
                        s += q_nr_query.data[(wi * m.n_heads + j * g + t) * hd + d] as f64;
                    }
                    qq[(wi * kh + j) * hd + d] = s / g as f64;
                }
            }
        }
        qq
    }

    /// Retaining-head MLP over an arbitrary run of local rows (pre-RoPE
    /// `q_nr`/`k_nr`/`v` carry only those rows; `qq` comes from
    /// [`SimEngine::query_mean`]). Row-wise by construction, so scoring a
    /// block in chunks is bit-identical to scoring it whole — the property
    /// chunked prefill rests on.
    fn score_rows(
        &self,
        lw: &LayerWeights,
        qq: &[f64],
        q_nr: &Tensor,
        k_nr: &Tensor,
        v: &Tensor,
    ) -> Tensor {
        let m = &self.model;
        let kh = m.n_kv_heads;
        let n = q_nr.shape[0];
        let feat_dim = 3 * m.head_dim() + 2;
        let mut scores = Tensor::zeros(vec![n, kh]);
        if self.scalar || n * kh <= 1 {
            let mut feat = vec![0f64; feat_dim];
            for i in 0..n {
                for j in 0..kh {
                    scores.data[i * kh + j] = self.score_one(lw, qq, q_nr, k_nr, v, i, j,
                                                             &mut feat);
                }
            }
        } else {
            // Each (row, kv-head) score is independent — fan out across the
            // engine pool. The unit index enumerates (i, j) in the same
            // order as the serial loop; writes are disjoint by construction.
            let sh = ShardedOut::new(&mut scores.data);
            self.pool.run(n * kh, &|u| {
                FEAT_SCRATCH.with(|cell| {
                    let mut feat = cell.borrow_mut();
                    feat.clear();
                    feat.resize(feat_dim, 0.0);
                    sh.set(u, self.score_one(lw, qq, q_nr, k_nr, v, u / kh, u % kh,
                                             &mut feat));
                });
            });
        }
        scores
    }

    /// One `(row, kv-head)` retaining score — the loop body of
    /// [`SimEngine::score_rows`], pure in `(i, j)` so the serial and pooled
    /// walks produce identical bits. `feat` is caller-provided scratch of
    /// length `3 * hd + 2`.
    #[allow(clippy::too_many_arguments)]
    fn score_one(
        &self,
        lw: &LayerWeights,
        qq: &[f64],
        q_nr: &Tensor,
        k_nr: &Tensor,
        v: &Tensor,
        i: usize,
        j: usize,
        feat: &mut [f64],
    ) -> f32 {
        let m = &self.model;
        let (hd, kh, g) = (m.head_dim(), m.n_kv_heads, m.gqa_groups());
        let w = qq.len() / (kh * hd);
        let scale = 1.0 / (hd as f64).sqrt();
        // Q component: mean over the GQA group.
        for d in 0..hd {
            let mut s = 0f64;
            for t in 0..g {
                s += q_nr.data[(i * m.n_heads + j * g + t) * hd + d] as f64;
            }
            feat[d] = s / g as f64;
        }
        let kb = (i * kh + j) * hd;
        for d in 0..hd {
            feat[hd + d] = k_nr.data[kb + d] as f64;
            feat[2 * hd + d] = v.data[kb + d] as f64;
        }
        // Query-similarity statistics over the embedded-query rows.
        let mut smax = f64::NEG_INFINITY;
        let mut smean = 0f64;
        for wi in 0..w {
            let mut dot = 0f64;
            for d in 0..hd {
                dot += qq[(wi * kh + j) * hd + d] * k_nr.data[kb + d] as f64;
            }
            let s = dot * scale;
            smax = smax.max(s);
            smean += s;
        }
        feat[3 * hd] = if w > 0 { smax } else { 0.0 };
        feat[3 * hd + 1] = if w > 0 { smean / w as f64 } else { 0.0 };
        // gelu MLP: scores[i, j] = gelu(feat·w1 + b1)·w2 + b2.
        let r = m.retaining_hidden;
        let mut out = lw.rh_b2 as f64;
        for u in 0..r {
            let mut hsum = lw.rh_b1[u] as f64;
            for (fi, &fv) in feat.iter().enumerate() {
                hsum += fv * lw.rh_w1.data[fi * r + u] as f64;
            }
            out += gelu(hsum) * lw.rh_w2.data[u] as f64;
        }
        out as f32
    }

    /// `build_features` + retaining-head MLP over the whole local block of
    /// the `[anchor | local]` layout — the full-layout wrapper over
    /// [`SimEngine::query_mean`] + [`SimEngine::score_rows`] (one code path
    /// with the chunked `layer_pre_chunk`, so the two are bit-identical).
    fn retaining_scores(
        &self,
        lw: &LayerWeights,
        q_nr: &Tensor,
        k_nr: &Tensor,
        v: &Tensor,
    ) -> Tensor {
        let n = q_nr.shape[0];
        let qq = self.query_mean(&q_nr.slice_rows(0, self.query_len));
        self.score_rows(
            lw,
            &qq,
            &q_nr.slice_rows(self.l_aq, n),
            &k_nr.slice_rows(self.l_aq, n),
            &v.slice_rows(self.l_aq, n),
        )
    }
}

impl ExecBackend for SimEngine {
    fn kind(&self) -> BackendKind {
        BackendKind::Sim
    }

    fn embed(&self, tokens: &[i32]) -> Result<Tensor> {
        let d = self.model.d_model;
        let vocab = self.model.vocab_size;
        let mut out = Tensor::zeros(vec![tokens.len(), d]);
        for (i, &t) in tokens.iter().enumerate() {
            if t < 0 || t as usize >= vocab {
                bail!("token {t} out of vocabulary (size {vocab})");
            }
            let src = t as usize * d;
            out.data[i * d..(i + 1) * d].copy_from_slice(&self.embed.data[src..src + d]);
        }
        Ok(out)
    }

    fn layer_pre(
        &self,
        layer: usize,
        hidden: &Tensor,
        pos_offset: i32,
    ) -> Result<(Tensor, Tensor, Tensor, Tensor)> {
        let lw = &self.layers[layer];
        let n = hidden.shape[0];
        if n != self.l_aq + self.block_len {
            bail!("layer_pre wants {} rows, got {n}", self.l_aq + self.block_len);
        }
        let (q_nr, k_nr, v) = self.project_qkv(lw, hidden);
        // Anchor rows at their true global positions 0..l_aq-1, local rows
        // at pos_offset.. — RoPE before compression so passed K blocks are
        // directly attendable on other hosts (§3.5).
        let positions: Vec<i32> = (0..self.l_aq as i32)
            .chain((0..self.block_len as i32).map(|i| pos_offset + i))
            .collect();
        let scores = self.retaining_scores(lw, &q_nr, &k_nr, &v);
        let q = rope(&q_nr, &positions, self.model.rope_theta);
        let k = rope(&k_nr, &positions, self.model.rope_theta);
        Ok((q, k, v, scores))
    }

    fn layer_post(
        &self,
        layer: usize,
        hidden: &Tensor,
        q: &Tensor,
        k: &Tensor,
        v: &Tensor,
        k_pass: &Tensor,
        v_pass: &Tensor,
        pass_len: i32,
        n_anchor: i32,
    ) -> Result<Tensor> {
        // The full layout is the row0 == 0 chunk: one code path with the
        // chunked machine, so chunked == one-shot bit-for-bit.
        self.layer_post_rows(layer, hidden, q, 0, k, v, k_pass, v_pass, pass_len, n_anchor)
    }

    fn layer_pre_chunk(
        &self,
        layer: usize,
        hidden_anchor: &Tensor,
        hidden_chunk: &Tensor,
        pos_chunk: &[i32],
    ) -> Result<(Tensor, Tensor, Tensor, Tensor)> {
        let lw = &self.layers[layer];
        if hidden_anchor.shape[0] != self.l_aq {
            bail!("layer_pre_chunk wants {} anchor rows, got {}", self.l_aq,
                  hidden_anchor.shape[0]);
        }
        if pos_chunk.len() != hidden_chunk.shape[0] {
            bail!("layer_pre_chunk: {} positions for {} rows", pos_chunk.len(),
                  hidden_chunk.shape[0]);
        }
        // The compressor reads the embedded-query rows pre-RoPE; projecting
        // just those rows equals projecting the whole anchor and slicing
        // (RMSNorm + matmul are row-wise). They are re-projected per chunk
        // — l_q rows against a chunk's worth of work — to keep the trait
        // stateless across chunk steps; a fused production kernel would
        // carry the query features in its per-layer state instead
        // (docs/ADR-002-chunked-prefill.md, "Consequences").
        let (q_nr_query, _, _) =
            self.project_qkv(lw, &hidden_anchor.slice_rows(0, self.query_len));
        let qq = self.query_mean(&q_nr_query);
        let (q_nr, k_nr, v) = self.project_qkv(lw, hidden_chunk);
        let scores = self.score_rows(lw, &qq, &q_nr, &k_nr, &v);
        let q = rope(&q_nr, pos_chunk, self.model.rope_theta);
        let k = rope(&k_nr, pos_chunk, self.model.rope_theta);
        Ok((q, k, v, scores))
    }

    fn layer_post_rows(
        &self,
        layer: usize,
        hidden_rows: &Tensor,
        q_rows: &Tensor,
        row0: usize,
        k: &Tensor,
        v: &Tensor,
        k_pass: &Tensor,
        v_pass: &Tensor,
        pass_len: i32,
        n_anchor: i32,
    ) -> Result<Tensor> {
        let lw = &self.layers[layer];
        let l_aq = self.l_aq;
        let (pass_len, n_anchor) = (pass_len.max(0) as usize, n_anchor.max(0) as usize);
        let k_anchor = k.slice_rows(0, l_aq);
        let k_local = k.slice_rows(l_aq, k.shape[0]);
        let v_anchor = v.slice_rows(0, l_aq);
        let v_local = v.slice_rows(l_aq, v.shape[0]);
        let k_attn = Tensor::concat_rows(&[&k_anchor, k_pass, &k_local]);
        let v_attn = Tensor::concat_rows(&[&v_anchor, v_pass, &v_local]);
        let pass_max = self.pass_max;
        // The mask is a function of the ABSOLUTE layout row, so a chunk
        // starting at row0 sees exactly what the monolithic pass shows it.
        let seg = super::KvSeg { k: &k_attn, v: &v_attn, len: k_attn.shape[0] };
        let (att, _lse) = self.attn(q_rows, &[seg], |qi, kj| {
            apb_visible(l_aq, pass_max, n_anchor, pass_len, qi + row0, kj)
        });
        Ok(self.attn_tail(lw, hidden_rows, &att))
    }

    fn decode_pre(
        &self,
        layer: usize,
        hidden: &Tensor,
        pos: &[i32],
    ) -> Result<(Tensor, Tensor, Tensor)> {
        let lw = &self.layers[layer];
        let n = hidden.shape[0];
        if pos.len() != n {
            bail!("decode_pre: {} positions for {n} rows", pos.len());
        }
        let (q, k, v) = self.project_qkv(lw, hidden);
        Ok((
            rope(&q, pos, self.model.rope_theta),
            rope(&k, pos, self.model.rope_theta),
            v,
        ))
    }

    fn decode_attn(
        &self,
        q: &Tensor,
        k_cache: &Tensor,
        v_cache: &Tensor,
        cache_len: usize,
        self_causal: bool,
    ) -> Result<(Tensor, Tensor)> {
        let n = q.shape[0];
        let seg = super::KvSeg { k: k_cache, v: v_cache, len: k_cache.shape[0] };
        Ok(self.attn(q, &[seg], |qi, kj| {
            let visible_len = if self_causal {
                cache_len.saturating_sub(n - 1 - qi)
            } else {
                cache_len
            };
            kj < visible_len
        }))
    }

    /// Segmented-view decode attention through the engine's pooled kernel.
    /// Same visibility rule as the trait default; bit-identical to it (the
    /// visible key set and per-(row, head) accumulation order are equal).
    fn decode_attn_view(
        &self,
        q: &Tensor,
        view: &super::KvView<'_>,
        self_causal: bool,
    ) -> Result<(Tensor, Tensor)> {
        let n = q.shape[0];
        let total = view.len();
        Ok(self.attn(q, &view.segs(), |qi, kj| {
            let visible = if self_causal {
                total.saturating_sub(n - 1 - qi)
            } else {
                total
            };
            kj < visible
        }))
    }

    /// Fused batched decode attention: all sessions' rows in one pass, each
    /// row masked to its own cache's valid rows — a `[shared | private]`
    /// prefix-cache view or a plain private tail alike. Numerically
    /// identical to the per-row default (the dense attention is
    /// row-independent), but a single engine invocation — the sim twin of a
    /// batched decode kernel.
    fn decode_attn_batch(
        &self,
        q: &Tensor,
        caches: &[super::KvView<'_>],
    ) -> Result<(Tensor, Tensor)> {
        let (b, h, hd) = (q.shape[0], q.shape[1], q.shape[2]);
        if caches.len() != b {
            bail!("decode_attn_batch: {} rows, {} caches", b, caches.len());
        }
        let mut out = Tensor::zeros(vec![b, h, hd]);
        let mut lse = Tensor::zeros(vec![b, h]);
        if self.scalar {
            for (i, c) in caches.iter().enumerate() {
                let total = c.len();
                let (o, l) = masked_attention_seg_ref(&q.slice_rows(i, i + 1), &c.segs(),
                                                      |_, kj| kj < total);
                out.write_rows(i, &o);
                lse.write_rows(i, &l);
            }
            return Ok((out, lse));
        }
        // One work unit per (batch row × kv head), each pointed straight at
        // its absolute q/output row — no per-row q slices or out/lse
        // temporaries. Each unit builds its row's segment map in its own
        // thread's scratch; units run serial kernels, so the pool is never
        // re-entered.
        let kh = self.model.n_kv_heads;
        let g = h / kh;
        let scale = 1.0 / (hd as f64).sqrt();
        let nonce = DISPATCH_NONCE.fetch_add(1, Ordering::Relaxed);
        {
            let out_sh = ShardedOut::new(&mut out.data);
            let lse_sh = ShardedOut::new(&mut lse.data);
            self.pool.run(b * kh, &|u| {
                let (i, j) = (u / kh, u % kh);
                let c = &caches[i];
                let total = c.len();
                let segs = c.segs();
                SEG_MAP.with(|cell| {
                    let mut map = cell.borrow_mut();
                    map.clear();
                    let single = segs.len() == 1;
                    if !single {
                        for (si, s) in segs.iter().enumerate() {
                            map.extend((0..s.len).map(|r| (si as u32, r as u32)));
                        }
                    }
                    let ctx = SegAttn {
                        q,
                        segs: &segs,
                        map: &map,
                        single,
                        nk: total,
                        h,
                        kh,
                        g,
                        hd,
                        scale,
                        nonce,
                        row0: i,
                    };
                    ctx.unit(&|_, kj| kj < total, 0, j, &out_sh, &lse_sh);
                });
            });
        }
        Ok((out, lse))
    }

    /// Position-causal partial attention (ring / dense baselines) through
    /// the engine's pooled kernel — same rule as the trait default.
    fn attn_partial(
        &self,
        q: &Tensor,
        k: &Tensor,
        v: &Tensor,
        q_pos: &[i32],
        k_pos: &[i32],
    ) -> Result<(Tensor, Tensor)> {
        anyhow::ensure!(q.shape[0] == q_pos.len(),
                        "attn_partial: {} q rows, {} positions", q.shape[0], q_pos.len());
        anyhow::ensure!(k.shape[0] == k_pos.len(),
                        "attn_partial: {} k rows, {} positions", k.shape[0], k_pos.len());
        let seg = super::KvSeg { k, v, len: k.shape[0] };
        Ok(self.attn(q, &[seg], |qi, kj| k_pos[kj] <= q_pos[qi]))
    }

    fn decode_post(&self, layer: usize, hidden: &Tensor, att: &Tensor) -> Result<Tensor> {
        Ok(self.attn_tail(&self.layers[layer], hidden, att))
    }

    fn lm_head(&self, hidden: &Tensor) -> Result<Tensor> {
        let x = rmsnorm(hidden, &self.final_norm, self.model.rms_eps);
        Ok(matmul(&x, &self.lm_head_w))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine() -> SimEngine {
        SimEngine::new(&Config::sim_tiny()).unwrap()
    }

    #[test]
    fn weights_deterministic_across_hosts() {
        let cfg = Config::sim_tiny();
        let a = SimEngine::new(&cfg).unwrap();
        let b = SimEngine::new(&cfg).unwrap();
        assert_eq!(a.embed.data, b.embed.data);
        assert_eq!(a.layers[0].wq.data, b.layers[0].wq.data);
        let h = a.embed(&[1, 2, 3]).unwrap();
        let (qa, ..) = a.decode_pre(0, &h, &[5, 6, 7]).unwrap();
        let (qb, ..) = b.decode_pre(0, &h, &[5, 6, 7]).unwrap();
        assert_eq!(qa, qb);
    }

    #[test]
    fn decode_pre_per_row_positions_match_consecutive() {
        // Non-consecutive per-row positions (a continuous-batching step)
        // must equal the same rows roped individually at those positions.
        let e = engine();
        let h = e.embed(&[3, 9]).unwrap();
        let (q, k, _v) = e.decode_pre(0, &h, &[40, 17]).unwrap();
        let (q0, k0, _) = e.decode_pre(0, &h.slice_rows(0, 1), &[40]).unwrap();
        let (q1, k1, _) = e.decode_pre(0, &h.slice_rows(1, 2), &[17]).unwrap();
        assert_eq!(q.slice_rows(0, 1), q0);
        assert_eq!(q.slice_rows(1, 2), q1);
        assert_eq!(k.slice_rows(0, 1), k0);
        assert_eq!(k.slice_rows(1, 2), k1);
        assert!(e.decode_pre(0, &h, &[1]).is_err(), "position/row count mismatch");
    }

    #[test]
    fn decode_attn_batch_matches_per_row() {
        use crate::runtime::{ExecBackend, KvSeg, KvView};
        let e = engine();
        let (h, kh, hd) = (e.model.n_heads, e.model.n_kv_heads, e.model.head_dim());
        let mut rng = Rng::new(21);
        let rand = |rng: &mut Rng, shape: Vec<usize>| {
            let n: usize = shape.iter().product();
            Tensor::new(shape, (0..n).map(|_| rng.normal() as f32).collect()).unwrap()
        };
        let q = rand(&mut rng, vec![3, h, hd]);
        // Three "sessions" with caches of different valid lengths.
        let k1 = rand(&mut rng, vec![8, kh, hd]);
        let v1 = rand(&mut rng, vec![8, kh, hd]);
        let k2 = rand(&mut rng, vec![8, kh, hd]);
        let v2 = rand(&mut rng, vec![8, kh, hd]);
        let tail = |k, v, len| KvView { shared: None, tail: KvSeg { k, v, len } };
        let views = [
            tail(&k1, &v1, 5),
            tail(&k2, &v2, 2),
            tail(&k1, &v1, 0), // empty cache row
        ];
        let (out, lse) = e.decode_attn_batch(&q, &views).unwrap();
        assert_eq!(out.shape, vec![3, h, hd]);
        assert_eq!(lse.shape, vec![3, h]);
        for (i, view) in views.iter().enumerate() {
            let (o, l) = e
                .decode_attn(&q.slice_rows(i, i + 1), view.tail.k, view.tail.v,
                             view.tail.len, false)
                .unwrap();
            assert_eq!(out.slice_rows(i, i + 1), o, "row {i} out");
            assert_eq!(lse.slice_rows(i, i + 1), l, "row {i} lse");
        }
        // Empty-cache row follows the -inf LSE convention for the merge.
        assert!(lse.slice_rows(2, 3).data.iter().all(|&x| x == f32::NEG_INFINITY));
    }

    #[test]
    fn segmented_attention_bitwise_matches_contiguous() {
        // THE prefix-cache numeric anchor: attending a [shared | tail] view
        // must be BIT-identical (not merely close) to attending the
        // contiguous concatenation, for every split point — same key order,
        // same f64 accumulation order, one kernel.
        use crate::runtime::{ExecBackend, KvSeg, KvView};
        let e = engine();
        let (h, kh, hd) = (e.model.n_heads, e.model.n_kv_heads, e.model.head_dim());
        let mut rng = Rng::new(0x5E6);
        let rand = |rng: &mut Rng, shape: Vec<usize>| {
            let n: usize = shape.iter().product();
            Tensor::new(shape, (0..n).map(|_| rng.normal() as f32).collect()).unwrap()
        };
        let q = rand(&mut rng, vec![2, h, hd]);
        let nk = 9usize;
        let k = rand(&mut rng, vec![nk, kh, hd]);
        let v = rand(&mut rng, vec![nk, kh, hd]);
        for n_valid in [0usize, 1, 5, nk] {
            let (full, full_lse) =
                e.decode_attn(&q, &k, &v, n_valid, false).unwrap();
            for split in 0..=n_valid {
                let shared_k = k.slice_rows(0, split);
                let shared_v = v.slice_rows(0, split);
                let tail_k = k.slice_rows(split, nk); // padded past n_valid
                let tail_v = v.slice_rows(split, nk);
                let view = KvView {
                    shared: Some(KvSeg { k: &shared_k, v: &shared_v, len: split }),
                    tail: KvSeg { k: &tail_k, v: &tail_v, len: n_valid - split },
                };
                let (o, l) = e.decode_attn_view(&q, &view, false).unwrap();
                assert_eq!(o, full, "valid {n_valid} split {split} out");
                assert_eq!(l, full_lse, "valid {n_valid} split {split} lse");
            }
        }
        // Self-causal rule over the combined length: row 0 of a 2-row chunk
        // sees one key fewer than row 1, exactly as on a contiguous cache.
        let (full, full_lse) = e.decode_attn(&q, &k, &v, 6, true).unwrap();
        let sk = k.slice_rows(0, 4);
        let sv = v.slice_rows(0, 4);
        let tk = k.slice_rows(4, nk);
        let tv = v.slice_rows(4, nk);
        let view = KvView {
            shared: Some(KvSeg { k: &sk, v: &sv, len: 4 }),
            tail: KvSeg { k: &tk, v: &tv, len: 2 },
        };
        let (o, l) = e.decode_attn_view(&q, &view, true).unwrap();
        assert_eq!(o, full, "self-causal out");
        assert_eq!(l, full_lse, "self-causal lse");
    }

    #[test]
    fn different_seed_changes_weights() {
        let mut cfg = Config::sim_tiny();
        let a = SimEngine::new(&cfg).unwrap();
        cfg.seed += 1;
        let b = SimEngine::new(&cfg).unwrap();
        assert_ne!(a.embed.data, b.embed.data);
    }

    #[test]
    fn matmul_known_values() {
        let x = Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let w = Tensor::new(vec![2, 2], vec![5.0, 6.0, 7.0, 8.0]).unwrap();
        let y = matmul(&x, &w);
        assert_eq!(y.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn rmsnorm_unit_rows() {
        // A row of all-equal values has rms == |value|: output is sign(x)·w.
        let x = Tensor::new(vec![1, 4], vec![3.0, 3.0, 3.0, 3.0]).unwrap();
        let y = rmsnorm(&x, &[1.0, 1.0, 1.0, 2.0], 0.0);
        for (got, want) in y.data.iter().zip([1.0, 1.0, 1.0, 2.0]) {
            assert!((got - want).abs() < 1e-5, "{got} vs {want}");
        }
    }

    #[test]
    fn rope_identity_at_position_zero_and_preserves_norm() {
        let x = Tensor::new(vec![2, 1, 4], vec![1.0, 2.0, 3.0, 4.0, -1.0, 0.5, 2.0, 0.0])
            .unwrap();
        let y = rope(&x, &[0, 7], 1e4);
        assert_eq!(&y.data[..4], &x.data[..4], "position 0 must be identity");
        let n0: f32 = x.data[4..].iter().map(|v| v * v).sum();
        let n1: f32 = y.data[4..].iter().map(|v| v * v).sum();
        assert!((n0 - n1).abs() < 1e-4, "rotation must preserve norm");
    }

    #[test]
    fn attention_matches_manual_two_keys() {
        // 1 query, 2 visible keys, h=kh=1, hd=1: plain softmax of q·k.
        let q = Tensor::new(vec![1, 1, 1], vec![2.0]).unwrap();
        let k = Tensor::new(vec![2, 1, 1], vec![0.5, -1.0]).unwrap();
        let v = Tensor::new(vec![2, 1, 1], vec![10.0, 20.0]).unwrap();
        let (out, lse) = masked_attention(&q, &k, &v, |_, _| true);
        let (s0, s1): (f64, f64) = (2.0 * 0.5, 2.0 * -1.0); // scale = 1/sqrt(1)
        let (e0, e1) = (s0.exp(), s1.exp());
        let want = (e0 * 10.0 + e1 * 20.0) / (e0 + e1);
        assert!((out.data[0] as f64 - want).abs() < 1e-5);
        let want_lse = (e0 + e1).ln();
        assert!((lse.data[0] as f64 - want_lse).abs() < 1e-5);
    }

    #[test]
    fn attention_no_visible_keys_is_zero_with_neg_inf_lse() {
        let q = Tensor::new(vec![1, 2, 2], vec![1.0; 4]).unwrap();
        let k = Tensor::new(vec![3, 1, 2], vec![1.0; 6]).unwrap();
        let v = k.clone();
        let (out, lse) = masked_attention(&q, &k, &v, |_, _| false);
        assert!(out.data.iter().all(|&x| x == 0.0));
        assert!(lse.data.iter().all(|&x| x == f32::NEG_INFINITY));
    }

    #[test]
    fn apb_mask_semantics() {
        let (l_aq, pass_max) = (3, 4);
        // Anchor query 1: causal inside anchor only.
        assert!(apb_visible(l_aq, pass_max, 3, 2, 1, 0));
        assert!(apb_visible(l_aq, pass_max, 3, 2, 1, 1));
        assert!(!apb_visible(l_aq, pass_max, 3, 2, 1, 2));
        assert!(!apb_visible(l_aq, pass_max, 3, 2, 1, 3)); // no passing keys
        assert!(!apb_visible(l_aq, pass_max, 3, 2, 1, 7)); // no local keys
        // Local query 0 (qi = 3): anchor prefix, passing prefix, self.
        assert!(apb_visible(l_aq, pass_max, 3, 2, 3, 0));
        assert!(apb_visible(l_aq, pass_max, 3, 2, 3, 2));
        assert!(apb_visible(l_aq, pass_max, 3, 2, 3, 3)); // passing 0 < pass_len
        assert!(apb_visible(l_aq, pass_max, 3, 2, 3, 4)); // passing 1 < pass_len
        assert!(!apb_visible(l_aq, pass_max, 3, 2, 3, 5)); // passing 2 >= pass_len
        assert!(apb_visible(l_aq, pass_max, 3, 2, 3, 7)); // own local position
        assert!(!apb_visible(l_aq, pass_max, 3, 2, 3, 8)); // future local
        // n_anchor = 0 (host 0): local queries see no anchor keys at all.
        assert!(!apb_visible(l_aq, pass_max, 0, 2, 3, 0));
        // But anchor rows still self-attend causally (outputs discarded).
        assert!(apb_visible(l_aq, pass_max, 0, 2, 0, 0));
    }

    #[test]
    fn retaining_scores_rank_query_matching_tokens_first() {
        // Put the query token inside the local block: its sim_max feature
        // must dominate, so the crafted retaining head ranks it on top.
        let e = engine();
        let cfg = Config::sim_tiny();
        let a = &cfg.apb;
        let needle = 42i32;
        let mut tokens = vec![0i32; a.n_tot()];
        // Anchor query rows carry the needle token.
        for slot in tokens.iter_mut().take(a.query_len) {
            *slot = needle;
        }
        // Local block: distinct filler tokens, needle planted at local row 5.
        for (i, slot) in tokens.iter_mut().enumerate().skip(a.l_aq()) {
            *slot = 1 + (i as i32 % 30);
        }
        tokens[a.l_aq() + 5] = needle;
        let hidden = e.embed(&tokens).unwrap();
        let (_q, _k, _v, scores) = e.layer_pre(0, &hidden, a.query_len as i32).unwrap();
        assert_eq!(scores.shape, vec![a.block_len, cfg.model.n_kv_heads]);
        for j in 0..cfg.model.n_kv_heads {
            let needle_score = scores.at2(5, j);
            let mut rank = 0;
            for i in 0..a.block_len {
                if scores.at2(i, j) > needle_score {
                    rank += 1;
                }
            }
            assert!(
                rank < a.passing_len,
                "head {j}: needle rank {rank} not within top l_p = {}",
                a.passing_len
            );
        }
    }

    #[test]
    fn decode_attn_respects_cache_len_and_self_causal() {
        let e = engine();
        let hd = e.model.head_dim();
        let (h, kh) = (e.model.n_heads, e.model.n_kv_heads);
        let mut rng = Rng::new(9);
        let rand = |rng: &mut Rng, shape: Vec<usize>| {
            let n: usize = shape.iter().product();
            Tensor::new(shape, (0..n).map(|_| rng.normal() as f32).collect()).unwrap()
        };
        let q = rand(&mut rng, vec![2, h, hd]);
        let kc = rand(&mut rng, vec![8, kh, hd]);
        let vc = rand(&mut rng, vec![8, kh, hd]);
        // cache_len 4, self_causal: row 0 sees 3 keys, row 1 sees 4.
        let (_out, lse) = e.decode_attn(&q, &kc, &vc, 4, true).unwrap();
        let (_o3, lse3) = masked_attention(&q, &kc, &vc, |qi, kj| kj < 3 + qi);
        for (a, b) in lse.data.iter().zip(&lse3.data) {
            assert!((a - b).abs() < 1e-5);
        }
        // Empty cache, not self-causal: all -inf.
        let (out0, lse0) = e.decode_attn(&q, &kc, &vc, 0, false).unwrap();
        assert!(out0.data.iter().all(|&x| x == 0.0));
        assert!(lse0.data.iter().all(|&x| x == f32::NEG_INFINITY));
    }

    #[test]
    fn attn_partial_blocks_merge_to_full_causal() {
        // Splitting the key set into "ring blocks" and merging the partials
        // must reproduce the single-pass causal attention — the numeric
        // core of the RingAttn == Dense invariant.
        use crate::runtime::ExecBackend;
        use crate::util::tensor::merge_partials;
        let e = engine();
        let (h, kh, hd) = (e.model.n_heads, e.model.n_kv_heads, e.model.head_dim());
        let mut rng = Rng::new(31);
        let rand = |rng: &mut Rng, shape: Vec<usize>| {
            let n: usize = shape.iter().product();
            Tensor::new(shape, (0..n).map(|_| rng.normal() as f32).collect()).unwrap()
        };
        let (nq, nk) = (5usize, 9usize);
        let q = rand(&mut rng, vec![nq, h, hd]);
        let k = rand(&mut rng, vec![nk, kh, hd]);
        let v = rand(&mut rng, vec![nk, kh, hd]);
        // Queries sit at the tail of the sequence; keys cover 0..nk.
        let q_pos: Vec<i32> = (0..nq as i32).map(|i| (nk as i32 - nq as i32) + i).collect();
        let k_pos: Vec<i32> = (0..nk as i32).collect();
        let (full, _) = e.attn_partial(&q, &k, &v, &q_pos, &k_pos).unwrap();
        // Two uneven blocks, as two hosts of a ring would hold them.
        let split = 4usize;
        let (o1, l1) = e
            .attn_partial(&q, &k.slice_rows(0, split), &v.slice_rows(0, split),
                          &q_pos, &k_pos[..split])
            .unwrap();
        let (o2, l2) = e
            .attn_partial(&q, &k.slice_rows(split, nk), &v.slice_rows(split, nk),
                          &q_pos, &k_pos[split..])
            .unwrap();
        let merged = merge_partials(&[o1, o2], &[l1, l2]);
        assert!(merged.max_abs_diff(&full) < 1e-5);
        // A block entirely in the future yields the -inf convention.
        let future_pos = vec![100i32; nk];
        let (of, lf) = e.attn_partial(&q, &k, &v, &[0; 5], &future_pos).unwrap();
        assert!(of.data.iter().all(|&x| x == 0.0));
        assert!(lf.data.iter().all(|&x| x == f32::NEG_INFINITY));
        // Row/position count mismatches are rejected.
        assert!(e.attn_partial(&q, &k, &v, &q_pos[..2], &k_pos).is_err());
        assert!(e.attn_partial(&q, &k, &v, &q_pos, &k_pos[..2]).is_err());
    }

    #[test]
    fn layer_pre_chunk_bitwise_matches_full_layer_pre() {
        // The chunked-prefill invariant at stage level: projecting/roping/
        // scoring an arbitrary run of local rows equals the matching rows of
        // the monolithic layer_pre, bit for bit.
        let e = engine();
        let cfg = Config::sim_tiny();
        let a = &cfg.apb;
        let mut rng = Rng::new(77);
        let tokens: Vec<i32> = (0..a.n_tot())
            .map(|_| rng.range(1, cfg.model.vocab_size as i64) as i32)
            .collect();
        let hidden = e.embed(&tokens).unwrap();
        let pos_offset = (a.query_len + 2 * a.block_len) as i32; // host 2
        let (q, k, v, scores) = e.layer_pre(0, &hidden, pos_offset).unwrap();
        let anchor = hidden.slice_rows(0, a.l_aq());
        // Uneven partition of the local block, including a 1-row chunk.
        for pair in [0usize, 1, 7, a.block_len].windows(2) {
            let (c0, c1) = (pair[0], pair[1]);
            let rows = hidden.slice_rows(a.l_aq() + c0, a.l_aq() + c1);
            let pos: Vec<i32> = (c0 as i32..c1 as i32).map(|i| pos_offset + i).collect();
            let (qc, kc, vc, sc) = e.layer_pre_chunk(0, &anchor, &rows, &pos).unwrap();
            assert_eq!(qc, q.slice_rows(a.l_aq() + c0, a.l_aq() + c1), "q {c0}..{c1}");
            assert_eq!(kc, k.slice_rows(a.l_aq() + c0, a.l_aq() + c1), "k {c0}..{c1}");
            assert_eq!(vc, v.slice_rows(a.l_aq() + c0, a.l_aq() + c1), "v {c0}..{c1}");
            assert_eq!(sc, scores.slice_rows(c0, c1), "scores {c0}..{c1}");
        }
        // Wrong anchor row count is rejected.
        assert!(e
            .layer_pre_chunk(0, &hidden.slice_rows(0, 1), &anchor, &[0])
            .is_err());
    }

    #[test]
    fn layer_post_rows_bitwise_matches_full_layer_post() {
        let e = engine();
        let cfg = Config::sim_tiny();
        let a = &cfg.apb;
        let mut rng = Rng::new(78);
        let tokens: Vec<i32> = (0..a.n_tot())
            .map(|_| rng.range(1, cfg.model.vocab_size as i64) as i32)
            .collect();
        let hidden = e.embed(&tokens).unwrap();
        let (q, k, v, _s) = e.layer_pre(0, &hidden, a.query_len as i32).unwrap();
        let rand = |rng: &mut Rng, shape: Vec<usize>| {
            let n: usize = shape.iter().product();
            Tensor::new(shape, (0..n).map(|_| rng.normal() as f32).collect()).unwrap()
        };
        let k_pass = rand(&mut rng, vec![a.pass_max(), cfg.model.n_kv_heads,
                                         cfg.model.head_dim()]);
        let v_pass = rand(&mut rng, vec![a.pass_max(), cfg.model.n_kv_heads,
                                         cfg.model.head_dim()]);
        let (pass_len, n_anchor) = (a.passing_len as i32, a.l_aq() as i32);
        let full = e
            .layer_post(0, &hidden, &q, &k, &v, &k_pass, &v_pass, pass_len, n_anchor)
            .unwrap();
        // Anchor+first-local-chunk, then the rest: both must equal the
        // matching rows of the monolithic pass.
        let cut = a.l_aq() + 5;
        for (r0, r1) in [(0usize, cut), (cut, a.n_tot())] {
            let out = e
                .layer_post_rows(0, &hidden.slice_rows(r0, r1), &q.slice_rows(r0, r1),
                                 r0, &k, &v, &k_pass, &v_pass, pass_len, n_anchor)
                .unwrap();
            assert_eq!(out, full.slice_rows(r0, r1), "rows {r0}..{r1}");
        }
    }

    #[test]
    fn lm_head_shape_and_finite() {
        let e = engine();
        let h = e.embed(&[3, 4]).unwrap();
        let logits = e.lm_head(&h).unwrap();
        assert_eq!(logits.shape, vec![2, e.model.vocab_size]);
        assert!(logits.data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn embed_rejects_out_of_vocab() {
        let e = engine();
        assert!(e.embed(&[-1]).is_err());
        assert!(e.embed(&[e.model.vocab_size as i32]).is_err());
    }
}
