//! PJRT runtime (behind the `pjrt` cargo feature): load HLO-text artifacts
//! produced by `python/compile/aot.py`, compile them on the CPU PJRT client,
//! and execute them from the coordinator hot path through [`ExecBackend`].
//!
//! Two deliberate performance choices (measured in the committed bench
//! artifacts, `BENCH_runtime.json` / `BENCH_decode.json`):
//!  * model weights are uploaded to device buffers ONCE per engine and
//!    executables run through `execute_b`, so the per-call cost is only the
//!    activation transfers;
//!  * one `Engine` per simulated host — mirroring the paper's one-process-
//!    per-GPU topology and keeping PJRT state thread-local.
//!
//! Artifact names are static-shape specialized (`embed_prefill` /
//! `embed_query` / `embed_step`, `decode_*_query` / `decode_*_step`); the
//! trait impl dispatches on the runtime chunk length.
//!
//! Known trade-off of the trait-granularity refactor: the pre-trait hot
//! path staged the hidden buffer once per layer (shared by layer_pre and
//! layer_post) and loop-invariant scalars (pos / pass_len / n_anchor) once
//! per pass; the typed stage methods re-upload them per call. That costs
//! O(n_layers) extra host-to-device transfers per pass versus the
//! pre-trait `BENCH_runtime.json` numbers. Recover it, if it matters again, by
//! adding staged-buffer caching inside this backend (keyed on the hidden
//! pointer / scalar value), not by widening the trait.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};
use xla::{
    ElementType, HloModuleProto, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable,
    XlaComputation,
};

use crate::config::{BackendKind, Config};
use crate::util::blob::Blob;
use crate::util::json::Json;
use crate::util::tensor::Tensor;

use super::ExecBackend;

/// Input/output declaration recorded by the AOT manifest.
#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub dtype: String,
    pub shape: Vec<usize>,
}

pub struct Artifact {
    pub name: String,
    pub exe: PjRtLoadedExecutable,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

/// A per-host PJRT engine holding the compiled executables and the
/// device-resident weight buffers.
pub struct Engine {
    pub client: PjRtClient,
    cfg: Config,
    artifacts: BTreeMap<String, Artifact>,
    weights: BTreeMap<String, PjRtBuffer>,
}

fn parse_iospec(v: &Json, default_name: &str) -> Result<IoSpec> {
    Ok(IoSpec {
        name: v
            .get("name")
            .and_then(|n| n.as_str())
            .unwrap_or(default_name)
            .to_string(),
        dtype: v.req("dtype")?.as_str().context("dtype")?.to_string(),
        shape: v.req("shape")?.usize_vec().context("shape")?,
    })
}

impl Engine {
    /// Compile every artifact in the manifest and upload all weights.
    pub fn load(cfg: &Config) -> Result<Engine> {
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        let manifest_arts = cfg
            .manifest
            .req("artifacts")?
            .as_obj()
            .context("manifest artifacts not an object")?;
        let mut artifacts = BTreeMap::new();
        for (name, meta) in manifest_arts {
            let file = meta.req("file")?.as_str().context("artifact file")?;
            let path = cfg.dir.join(file);
            let proto = HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow::anyhow!("parsing {}: {e:?}", path.display()))?;
            let comp = XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compiling {name}: {e:?}"))?;
            let inputs = meta
                .req("inputs")?
                .as_arr()
                .context("inputs")?
                .iter()
                .map(|v| parse_iospec(v, "?"))
                .collect::<Result<Vec<_>>>()?;
            let outputs = meta
                .req("outputs")?
                .as_arr()
                .context("outputs")?
                .iter()
                .enumerate()
                .map(|(i, v)| parse_iospec(v, &format!("out{i}")))
                .collect::<Result<Vec<_>>>()?;
            artifacts.insert(
                name.clone(),
                Artifact { name: name.clone(), exe, inputs, outputs },
            );
        }
        if artifacts.is_empty() {
            bail!("no artifacts loaded from {}", cfg.dir.display());
        }

        // Upload weights once.
        let blob = Blob::load(&cfg.dir, cfg.manifest.req("weights")?)?;
        let mut weights = BTreeMap::new();
        for name in blob.names().map(str::to_string).collect::<Vec<_>>() {
            let t = blob.tensor(&name)?;
            let buf = client
                .buffer_from_host_buffer::<f32>(&t.data, &t.shape, None)
                .map_err(|e| anyhow::anyhow!("uploading weight {name}: {e:?}"))?;
            weights.insert(name, buf);
        }
        Ok(Engine { client, cfg: cfg.clone(), artifacts, weights })
    }

    pub fn artifact_names(&self) -> Vec<&str> {
        self.artifacts.keys().map(|s| s.as_str()).collect()
    }

    pub fn artifact(&self, name: &str) -> Result<&Artifact> {
        self.artifacts
            .get(name)
            .with_context(|| format!("artifact '{name}' not loaded"))
    }

    pub fn weight(&self, name: &str) -> Result<&PjRtBuffer> {
        self.weights
            .get(name)
            .with_context(|| format!("weight '{name}' not found"))
    }

    /// Per-layer weight lookup (`layers.{i}.{short}`).
    pub fn layer_weight(&self, layer: usize, short: &str) -> Result<&PjRtBuffer> {
        self.weight(&format!("layers.{layer}.{short}"))
    }

    pub fn upload_f32(&self, t: &Tensor) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer::<f32>(&t.data, &t.shape, None)
            .map_err(|e| anyhow::anyhow!("upload f32 {:?}: {e:?}", t.shape))
    }

    pub fn upload_i32(&self, v: &[i32], shape: &[usize]) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer::<i32>(v, shape, None)
            .map_err(|e| anyhow::anyhow!("upload i32 {shape:?}: {e:?}"))
    }

    pub fn scalar_i32(&self, v: i32) -> Result<PjRtBuffer> {
        self.upload_i32(&[v], &[])
    }

    /// Execute an artifact with pre-staged buffers; outputs decoded to
    /// host-side f32 tensors using the manifest shapes.
    pub fn exec(&self, name: &str, args: &[&PjRtBuffer]) -> Result<Vec<Tensor>> {
        let art = self.artifact(name)?;
        if args.len() != art.inputs.len() {
            bail!(
                "artifact '{name}' wants {} inputs, got {}",
                art.inputs.len(),
                args.len()
            );
        }
        let outs = art
            .exe
            .execute_b(args)
            .map_err(|e| anyhow::anyhow!("executing {name}: {e:?}"))?;
        let tuple = outs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching {name} result: {e:?}"))?;
        // aot.py lowers with return_tuple=True: single tuple literal.
        let parts: Vec<Literal> = tuple
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untupling {name}: {e:?}"))?;
        if parts.len() != art.outputs.len() {
            bail!(
                "artifact '{name}': manifest says {} outputs, tuple has {}",
                art.outputs.len(),
                parts.len()
            );
        }
        let mut tensors = Vec::with_capacity(parts.len());
        for (lit, spec) in parts.into_iter().zip(&art.outputs) {
            let lit = match lit.ty() {
                Ok(ElementType::F32) => lit,
                _ => lit
                    .convert(ElementType::F32.primitive_type())
                    .map_err(|e| anyhow::anyhow!("converting {name} output: {e:?}"))?,
            };
            let data = lit
                .to_vec::<f32>()
                .map_err(|e| anyhow::anyhow!("reading {name} output: {e:?}"))?;
            tensors.push(Tensor::new(spec.shape.clone(), data)?);
        }
        Ok(tensors)
    }

    /// Convenience: execute with host-side values (tests / cold paths; the
    /// hot path stages buffers itself and reuses weight buffers).
    pub fn exec_t(&self, name: &str, args: &[HostArg]) -> Result<Vec<Tensor>> {
        let staged: Vec<PjRtBuffer> = args
            .iter()
            .map(|a| match a {
                HostArg::F32(t) => self.upload_f32(t),
                HostArg::I32s(v, shape) => self.upload_i32(v, shape),
                HostArg::ScalarI32(v) => self.scalar_i32(*v),
            })
            .collect::<Result<Vec<_>>>()?;
        let refs: Vec<&PjRtBuffer> = staged.iter().collect();
        self.exec(name, &refs)
    }

    /// Static-shape artifact tag for a decode chunk of `n` tokens.
    ///
    /// The `_query` / `_step` artifact families are the SAME stage function
    /// lowered at two static chunk shapes (aot.py), so shape is the only
    /// thing that distinguishes them — when `query_len == 1` the families
    /// coincide and either dispatch is correct by construction. If aot.py
    /// ever specializes them semantically, this must thread an explicit tag
    /// instead.
    fn chunk_tag(&self, n: usize) -> &'static str {
        if n == self.cfg.apb.query_len {
            "query"
        } else {
            "step"
        }
    }
}

/// Host-side argument for `exec_t` cold paths.
pub enum HostArg {
    F32(Tensor),
    I32s(Vec<i32>, Vec<usize>),
    ScalarI32(i32),
}

impl ExecBackend for Engine {
    fn kind(&self) -> BackendKind {
        BackendKind::Pjrt
    }

    fn embed(&self, tokens: &[i32]) -> Result<Tensor> {
        let n = tokens.len();
        let name = if n == self.cfg.apb.n_tot() {
            "embed_prefill"
        } else if n == self.cfg.apb.query_len {
            "embed_query"
        } else {
            "embed_step"
        };
        let tok_buf = self.upload_i32(tokens, &[n])?;
        Ok(self.exec(name, &[&tok_buf, self.weight("embed")?])?.remove(0))
    }

    fn layer_pre(
        &self,
        layer: usize,
        hidden: &Tensor,
        pos_offset: i32,
    ) -> Result<(Tensor, Tensor, Tensor, Tensor)> {
        let h_buf = self.upload_f32(hidden)?;
        let pos_buf = self.scalar_i32(pos_offset)?;
        let mut outs = self.exec(
            "layer_pre",
            &[
                &h_buf,
                &pos_buf,
                self.layer_weight(layer, "attn_norm")?,
                self.layer_weight(layer, "wq")?,
                self.layer_weight(layer, "wk")?,
                self.layer_weight(layer, "wv")?,
                self.layer_weight(layer, "rh_w1")?,
                self.layer_weight(layer, "rh_b1")?,
                self.layer_weight(layer, "rh_w2")?,
                self.layer_weight(layer, "rh_b2")?,
            ],
        )?;
        let scores = outs.pop().context("layer_pre scores")?;
        let v = outs.pop().context("layer_pre v")?;
        let k = outs.pop().context("layer_pre k")?;
        let q = outs.pop().context("layer_pre q")?;
        Ok((q, k, v, scores))
    }

    fn layer_post(
        &self,
        layer: usize,
        hidden: &Tensor,
        q: &Tensor,
        k: &Tensor,
        v: &Tensor,
        k_pass: &Tensor,
        v_pass: &Tensor,
        pass_len: i32,
        n_anchor: i32,
    ) -> Result<Tensor> {
        let args = [
            self.upload_f32(hidden)?,
            self.upload_f32(q)?,
            self.upload_f32(k)?,
            self.upload_f32(v)?,
            self.upload_f32(k_pass)?,
            self.upload_f32(v_pass)?,
            self.scalar_i32(pass_len)?,
            self.scalar_i32(n_anchor)?,
        ];
        let mut refs: Vec<&PjRtBuffer> = args.iter().collect();
        refs.push(self.layer_weight(layer, "wo")?);
        refs.push(self.layer_weight(layer, "ffn_norm")?);
        refs.push(self.layer_weight(layer, "w_gate")?);
        refs.push(self.layer_weight(layer, "w_up")?);
        refs.push(self.layer_weight(layer, "w_down")?);
        Ok(self.exec("layer_post", &refs)?.remove(0))
    }

    fn decode_pre(
        &self,
        layer: usize,
        hidden: &Tensor,
        pos: &[i32],
    ) -> Result<(Tensor, Tensor, Tensor)> {
        let n = hidden.shape[0];
        if pos.len() != n {
            bail!("decode_pre: {} positions for {n} rows", pos.len());
        }
        // The AOT'd decode_pre artifacts take a scalar pos0 and derive
        // pos0+i internally, so a consecutive run executes in one call.
        // Non-consecutive per-row positions (a continuous-batching step
        // stacking rows of different sessions) fall back to one single-row
        // call per row against the `_step` artifact.
        let consecutive = pos.windows(2).all(|w| w[1] == w[0] + 1);
        if consecutive {
            let tag = self.chunk_tag(n);
            let h_buf = self.upload_f32(hidden)?;
            let pos_buf = self.scalar_i32(pos[0])?;
            let mut outs = self.exec(
                &format!("decode_pre_{tag}"),
                &[
                    &h_buf,
                    &pos_buf,
                    self.layer_weight(layer, "attn_norm")?,
                    self.layer_weight(layer, "wq")?,
                    self.layer_weight(layer, "wk")?,
                    self.layer_weight(layer, "wv")?,
                ],
            )?;
            let v = outs.pop().context("decode_pre v")?;
            let k = outs.pop().context("decode_pre k")?;
            let q = outs.pop().context("decode_pre q")?;
            return Ok((q, k, v));
        }
        let mut qs = Vec::with_capacity(n);
        let mut ks = Vec::with_capacity(n);
        let mut vs = Vec::with_capacity(n);
        for i in 0..n {
            let (q, k, v) =
                self.decode_pre(layer, &hidden.slice_rows(i, i + 1), &pos[i..i + 1])?;
            qs.push(q);
            ks.push(k);
            vs.push(v);
        }
        let cat = |ts: &[Tensor]| Tensor::concat_rows(&ts.iter().collect::<Vec<_>>());
        Ok((cat(&qs), cat(&ks), cat(&vs)))
    }

    fn decode_attn(
        &self,
        q: &Tensor,
        k_cache: &Tensor,
        v_cache: &Tensor,
        cache_len: usize,
        self_causal: bool,
    ) -> Result<(Tensor, Tensor)> {
        let tag = self.chunk_tag(q.shape[0]);
        let args = [
            self.upload_f32(q)?,
            self.upload_f32(k_cache)?,
            self.upload_f32(v_cache)?,
            self.scalar_i32(cache_len as i32)?,
            self.scalar_i32(self_causal as i32)?,
        ];
        let refs: Vec<&PjRtBuffer> = args.iter().collect();
        let mut outs = self.exec(&format!("decode_attn_{tag}"), &refs)?;
        let lse = outs.pop().context("decode_attn lse")?;
        let out = outs.pop().context("decode_attn out")?;
        Ok((out, lse))
    }

    fn decode_post(&self, layer: usize, hidden: &Tensor, att: &Tensor) -> Result<Tensor> {
        let tag = self.chunk_tag(hidden.shape[0]);
        let args = [self.upload_f32(hidden)?, self.upload_f32(att)?];
        let mut refs: Vec<&PjRtBuffer> = args.iter().collect();
        refs.push(self.layer_weight(layer, "wo")?);
        refs.push(self.layer_weight(layer, "ffn_norm")?);
        refs.push(self.layer_weight(layer, "w_gate")?);
        refs.push(self.layer_weight(layer, "w_up")?);
        refs.push(self.layer_weight(layer, "w_down")?);
        Ok(self.exec(&format!("decode_post_{tag}"), &refs)?.remove(0))
    }

    fn lm_head(&self, hidden: &Tensor) -> Result<Tensor> {
        let tag = self.chunk_tag(hidden.shape[0]);
        let h_buf = self.upload_f32(hidden)?;
        Ok(self
            .exec(
                &format!("lm_head_{tag}"),
                &[&h_buf, self.weight("final_norm")?, self.weight("lm_head")?],
            )?
            .remove(0))
    }
}
