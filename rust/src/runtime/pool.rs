//! `SimPool` — a hand-rolled, std-only thread pool for row-parallel sim
//! kernels (`docs/ADR-005-sim-perf.md`).
//!
//! The segmented attention kernel, the retaining-head scorer and batched
//! decode all decompose into independent (query-row × kv-head) work units:
//! no two units share an accumulator, so distributing them across threads
//! cannot change a single bit of the result — only which core computes it.
//! This pool exploits exactly that shape and nothing more:
//!
//! * one job at a time (`run` blocks until every unit completed), so a
//!   borrowed closure can be handed to workers behind a raw pointer whose
//!   pointee provably outlives every use;
//! * the caller participates in draining the task queue — a pool sized 1
//!   has zero worker threads and `run` degenerates to a plain serial loop;
//! * re-entrant `run` calls (a task spawning sub-work on the same pool)
//!   fall back to inline execution instead of deadlocking on the job slot.
//!
//! Sizing composes with `Driver::Threaded` (one pool per `SimEngine`, one
//! engine per host thread): `SimEngine::new` resolves
//! `Config::sim_threads` = 0 to `APB_SIM_THREADS`, else to
//! `available_parallelism / n_hosts`, so H host threads × T pool threads
//! stays at roughly the machine's core count rather than H × cores.

use std::cell::Cell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Type-erased pointer to the job closure. Sound to send across threads
/// because (a) the pointee is `Sync` (enforced by `SimPool::run`'s
/// signature) and (b) `run` does not return until every task finished, so
/// the borrow outlives every dereference.
#[derive(Clone, Copy)]
struct TaskPtr(*const (dyn Fn(usize) + Sync));

// SAFETY: see `TaskPtr` docs — the pointee is `Sync` and outlives all use.
unsafe impl Send for TaskPtr {}

struct Job {
    f: TaskPtr,
    n_tasks: usize,
    /// Next unclaimed task index.
    next: usize,
    /// Tasks fully executed (claimed AND returned).
    done: usize,
    /// A worker-executed task panicked; `run` re-panics after the job.
    panicked: bool,
}

#[derive(Default)]
struct PoolState {
    job: Option<Job>,
    shutdown: bool,
}

struct Shared {
    state: Mutex<PoolState>,
    /// Workers wait here for a job (or shutdown).
    work_cv: Condvar,
    /// The `run` caller waits here for `done == n_tasks`.
    done_cv: Condvar,
}

thread_local! {
    /// True while this thread is executing pool work (worker threads for
    /// their whole life, the `run` caller for the span of the call) — the
    /// re-entrancy guard that turns nested `run` calls into inline loops.
    static IN_POOL: Cell<bool> = const { Cell::new(false) };
}

/// The pool. `Drop` signals shutdown and joins every worker, so engines
/// (and tests constructing many of them) never leak threads.
pub struct SimPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl SimPool {
    /// Build a pool that executes jobs on `threads` threads total: the
    /// `run` caller plus `threads - 1` spawned workers. `threads <= 1`
    /// spawns nothing and `run` is a plain serial loop.
    pub fn new(threads: usize) -> SimPool {
        let shared = Arc::new(Shared {
            state: Mutex::new(PoolState::default()),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let workers = (1..threads.max(1))
            .map(|_| {
                let sh = Arc::clone(&shared);
                std::thread::spawn(move || worker_loop(&sh))
            })
            .collect();
        SimPool { shared, workers }
    }

    /// Total threads that drain a job (caller + workers), always >= 1.
    pub fn threads(&self) -> usize {
        self.workers.len() + 1
    }

    /// Execute `f(0), f(1), ..., f(n_tasks - 1)` exactly once each, in
    /// unspecified order across the pool's threads, and return when ALL of
    /// them completed. Tasks must write only to disjoint state (see
    /// [`ShardedOut`]); under that contract the result is bit-identical to
    /// the serial loop whatever the schedule.
    pub fn run(&self, n_tasks: usize, f: &(dyn Fn(usize) + Sync)) {
        if n_tasks == 0 {
            return;
        }
        // Serial pool, trivial jobs, or a nested call from inside a task:
        // run inline. (Nested dispatch would wait on the job slot the outer
        // call still owns — a deadlock — so the guard is load-bearing.)
        if self.workers.is_empty() || n_tasks == 1 || IN_POOL.with(Cell::get) {
            for t in 0..n_tasks {
                f(t);
            }
            return;
        }
        IN_POOL.with(|c| c.set(true));
        let ptr = TaskPtr(f as *const (dyn Fn(usize) + Sync));
        {
            let mut st = self.shared.state.lock().unwrap();
            debug_assert!(st.job.is_none(), "SimPool::run re-entered with a live job");
            st.job = Some(Job { f: ptr, n_tasks, next: 0, done: 0, panicked: false });
        }
        self.shared.work_cv.notify_all();
        // The caller pulls tasks too: a pool is never idle while its owner
        // spins, and a 2-thread pool really uses 2 threads.
        loop {
            let t = {
                let mut st = self.shared.state.lock().unwrap();
                let job = st.job.as_mut().expect("job lives until run() clears it");
                if job.next >= job.n_tasks {
                    break;
                }
                let t = job.next;
                job.next += 1;
                t
            };
            f(t);
            let mut st = self.shared.state.lock().unwrap();
            let job = st.job.as_mut().expect("job lives until run() clears it");
            job.done += 1;
        }
        let panicked = {
            let mut st = self.shared.state.lock().unwrap();
            while st.job.as_ref().expect("job lives until run() clears it").done < n_tasks {
                st = self.shared.done_cv.wait(st).unwrap();
            }
            st.job.take().expect("job lives until run() clears it").panicked
        };
        IN_POOL.with(|c| c.set(false));
        assert!(!panicked, "SimPool worker task panicked");
    }
}

impl Drop for SimPool {
    fn drop(&mut self) {
        self.shared.state.lock().unwrap().shutdown = true;
        self.shared.work_cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(sh: &Shared) {
    IN_POOL.with(|c| c.set(true));
    loop {
        let (f, t, n_tasks) = {
            let mut st = sh.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                match st.job.as_mut() {
                    Some(job) if job.next < job.n_tasks => {
                        let t = job.next;
                        job.next += 1;
                        break (job.f, t, job.n_tasks);
                    }
                    // No job, or a drained one the caller is collecting:
                    // sleep until the next `run` (or shutdown) wakes us.
                    _ => st = sh.work_cv.wait(st).unwrap(),
                }
            }
        };
        // SAFETY: `run` keeps the closure alive until done == n_tasks, and
        // this dereference happens strictly before this task's `done`
        // increment below.
        let task = unsafe { &*f.0 };
        let panicked = catch_unwind(AssertUnwindSafe(|| task(t))).is_err();
        let mut st = sh.state.lock().unwrap();
        if let Some(job) = st.job.as_mut() {
            job.done += 1;
            job.panicked |= panicked;
            if job.done == job.n_tasks {
                sh.done_cv.notify_all();
            }
        }
    }
}

/// Write-only shared view of an output buffer for pool tasks.
///
/// Tasks produce disjoint slices of one output tensor (row × head-group
/// shards); this wrapper lets `Fn` closures write them through a shared
/// reference without handing out `&mut` aliases. Bounds are checked; the
/// DISJOINTNESS of concurrent writes is the caller's contract (trivially
/// held by the kernels: shard `(i, j)` writes only offsets derived from
/// `(i, j)`).
pub struct ShardedOut<'a> {
    ptr: *mut f32,
    len: usize,
    _marker: std::marker::PhantomData<&'a mut [f32]>,
}

// SAFETY: writes go to caller-guaranteed disjoint ranges of one allocation;
// distinct memory locations written from distinct threads are not a data
// race. Reads never happen through this type.
unsafe impl Send for ShardedOut<'_> {}
unsafe impl Sync for ShardedOut<'_> {}

impl<'a> ShardedOut<'a> {
    pub fn new(data: &'a mut [f32]) -> ShardedOut<'a> {
        ShardedOut {
            ptr: data.as_mut_ptr(),
            len: data.len(),
            _marker: std::marker::PhantomData,
        }
    }

    /// Copy `src` into `offset..offset + src.len()`.
    pub fn write(&self, offset: usize, src: &[f32]) {
        assert!(offset + src.len() <= self.len, "ShardedOut write out of bounds");
        // SAFETY: in-bounds (checked above); disjoint from every concurrent
        // write by the caller's sharding contract.
        unsafe {
            std::ptr::copy_nonoverlapping(src.as_ptr(), self.ptr.add(offset), src.len());
        }
    }

    /// Write one element at `offset`.
    pub fn set(&self, offset: usize, v: f32) {
        assert!(offset < self.len, "ShardedOut set out of bounds");
        // SAFETY: as in `write`.
        unsafe {
            self.ptr.add(offset).write(v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn serial_pool_runs_every_task_inline() {
        let pool = SimPool::new(1);
        assert_eq!(pool.threads(), 1);
        let hits = AtomicUsize::new(0);
        pool.run(17, &|t| {
            hits.fetch_add(t + 1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), (1..=17).sum());
    }

    #[test]
    fn parallel_pool_runs_each_task_exactly_once() {
        let pool = SimPool::new(4);
        assert_eq!(pool.threads(), 4);
        let mut out = vec![0f32; 256];
        let sh = ShardedOut::new(&mut out);
        pool.run(256, &|t| sh.set(t, t as f32 + 1.0));
        for (t, &v) in out.iter().enumerate() {
            assert_eq!(v, t as f32 + 1.0, "task {t} ran exactly once");
        }
    }

    #[test]
    fn pool_is_reusable_across_jobs() {
        let pool = SimPool::new(3);
        for round in 0..20 {
            let hits = AtomicUsize::new(0);
            pool.run(round + 2, &|_| {
                hits.fetch_add(1, Ordering::Relaxed);
            });
            assert_eq!(hits.load(Ordering::Relaxed), round + 2);
        }
    }

    #[test]
    fn nested_run_falls_back_inline() {
        let pool = SimPool::new(3);
        let hits = AtomicUsize::new(0);
        pool.run(4, &|_| {
            // A task re-entering the pool must not deadlock on the job slot.
            pool.run(8, &|_| {
                hits.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(hits.load(Ordering::Relaxed), 32);
    }

    #[test]
    fn sharded_out_writes_disjoint_slices() {
        let pool = SimPool::new(4);
        let rows = 64usize;
        let width = 7usize;
        let mut out = vec![0f32; rows * width];
        let sh = ShardedOut::new(&mut out);
        pool.run(rows, &|i| {
            let row: Vec<f32> = (0..width).map(|d| (i * width + d) as f32).collect();
            sh.write(i * width, &row);
        });
        for (j, &v) in out.iter().enumerate() {
            assert_eq!(v, j as f32);
        }
    }

    #[test]
    fn drop_joins_workers() {
        // Many short-lived pools must not wedge on shutdown.
        for _ in 0..8 {
            let pool = SimPool::new(4);
            pool.run(16, &|_| {});
            drop(pool);
        }
    }
}
