//! Synthetic serving workloads: deterministic, seeded request traces for
//! the SLO scheduler (`docs/ADR-006-slo-scheduling.md`).
//!
//! A [`TraceSpec`] describes traffic statistically — arrival process
//! ([`Arrival::Poisson`] or [`Arrival::Bursty`]), a heavy-tailed length
//! mix ([`LengthMix`]) blending short interactive requests with
//! block-scale long-context ones, a shared-corpus prefix-hit rate riding
//! the PR 5 prefix store, and per-class weights — and
//! [`generate`] expands it into a concrete [`Trace`]: a tick-stamped,
//! fully materialized request list. Everything downstream of the seed is
//! deterministic (xoshiro256** from [`crate::util::rng`], no wall clock),
//! so the same spec replays bit-identically under both cluster drivers —
//! the property `rust/tests/slo_scheduling.rs` and `driver_parity.rs`
//! pin via [`crate::coordinator::scheduler::ReplayFingerprint`].
//!
//! ## What "long" means here
//!
//! The sim config fixes the document and query geometry (`doc_len =
//! n_hosts * block_len`), so a trace cannot vary *token counts* per
//! request. Service-time heterogeneity — the thing that actually starves
//! FIFO queues — is modeled on the two axes the stack does expose per
//! request: the resumable-prefill granularity (`ApbOptions::chunk_tokens`,
//! where `Some(1)` turns one admission into a block-scale many-step
//! prefill occupying the admission seat for ~`L*(3*C+2)` scheduler ticks)
//! and the decode budget (`max_new`). A "long" request is therefore a
//! many-chunk, many-token [`Class::Batch`] request; a "short" one admits
//! in few chunks and decodes briefly.
//!
//! ## Prefix sharing
//!
//! The prefix-store digest covers the ENTIRE (config, doc, query, opts)
//! tuple, so hit-intended requests must reuse a corpus entry wholesale:
//! the trace pre-generates `corpus_size` (doc, query) pairs and each
//! short request either draws a fresh pair (miss) or replays a corpus
//! pair (hit after its first cold use) with identical options. Long
//! requests always draw fresh documents — block-scale contexts are
//! assumed unique.
//!
//! ## Multi-turn follow-ups and closed-loop sweeps
//!
//! A spec with `follow_up_rate > 0` (the `soak` spec) additionally emits
//! follow-up turns: replays of an earlier short request's exact
//! (doc, query) pair after a think-time gap, modeling multi-turn
//! conversations. Follow-ups hit the prefix store wholesale, so they are
//! the warm traffic the adaptive decode chooser
//! (`docs/ADR-007-adaptive-decode.md`) steers on. Besides the open-loop
//! [`run_trace`], [`run_trace_closed_loop`] holds a fixed
//! multiprogramming level and [`sweep_closed_loop`] maps out the
//! latency/goodput curve across levels.

pub mod http;

use anyhow::{bail, Result};

use crate::config::Config;
use crate::coordinator::scheduler::{Class, Request, Scheduler};
use crate::util::rng::Rng;

/// Arrival process for a trace, in scheduler ticks.
#[derive(Debug, Clone)]
pub enum Arrival {
    /// Poisson process: i.i.d. exponential gaps with the given mean (in
    /// ticks) between consecutive arrivals.
    Poisson { mean_gap_ticks: f64 },
    /// Bursty: `burst` requests arrive back-to-back on one tick, then the
    /// line goes quiet for `gap_ticks` ticks.
    Bursty { burst: usize, gap_ticks: u64 },
}

/// Heavy-tailed service-length mix (see the module docs for why length
/// here means chunk count + decode budget, not token count).
#[derive(Debug, Clone)]
pub struct LengthMix {
    /// Probability a request is long (block-scale prefill, Batch class).
    pub long_fraction: f64,
    /// `ApbOptions::chunk_tokens` override for long requests (small value
    /// ⇒ many resumable-prefill steps per admission).
    pub long_chunk_tokens: usize,
    /// Inclusive `max_new` range for short requests.
    pub short_max_new: (usize, usize),
    /// Inclusive `max_new` range for long requests.
    pub long_max_new: (usize, usize),
}

/// A statistical description of serving traffic; [`generate`] expands it
/// deterministically into a [`Trace`].
#[derive(Debug, Clone)]
pub struct TraceSpec {
    /// Spec name (CLI `--trace <name>`, `BENCH_serving.json`).
    pub name: &'static str,
    pub seed: u64,
    pub n_requests: usize,
    pub arrival: Arrival,
    pub mix: LengthMix,
    /// Probability a SHORT request replays a shared-corpus (doc, query)
    /// pair instead of drawing fresh tokens. With the prefix store
    /// enabled, every replay after the pair's first (cold) use is a hit.
    pub prefix_hit_rate: f64,
    /// How many distinct (doc, query) pairs the shared corpus holds.
    pub corpus_size: usize,
    /// Class weights for short requests, indexed by [`Class::index`]
    /// (long requests are always [`Class::Batch`]).
    pub class_weights: [f64; 3],
    /// Probability a short request spawns a follow-up turn: a later
    /// arrival replaying the SAME (doc, query) pair, modeling a
    /// multi-turn conversation at trace granularity. The replay hits the
    /// prefix store wholesale, so follow-up traffic is what steers the
    /// adaptive decode chooser (`docs/ADR-007-adaptive-decode.md`) toward
    /// pass-Q under sustained load.
    pub follow_up_rate: f64,
    /// Think-time gap, in ticks, between a request's arrival and its
    /// follow-up turn.
    pub follow_up_gap_ticks: u64,
}

impl TraceSpec {
    /// Look up a named spec (`smoke`, `adversarial`, `poisson`,
    /// `bursty`). Returns `None` for unknown names; callers list
    /// [`TraceSpec::NAMES`] in their usage text.
    pub fn by_name(name: &str) -> Option<TraceSpec> {
        match name {
            // CI-sized: a handful of shorts around one block-scale long,
            // with corpus sharing — small enough for the smoke gate,
            // adversarial enough that FIFO would starve the shorts.
            "smoke" => Some(TraceSpec {
                name: "smoke",
                seed: 0xAB5E,
                n_requests: 8,
                arrival: Arrival::Poisson { mean_gap_ticks: 2.0 },
                mix: LengthMix {
                    long_fraction: 0.2,
                    long_chunk_tokens: 1,
                    short_max_new: (2, 4),
                    long_max_new: (4, 8),
                },
                prefix_hit_rate: 0.5,
                corpus_size: 2,
                class_weights: [0.5, 0.5, 0.0],
                follow_up_rate: 0.0,
                follow_up_gap_ticks: 0,
            }),
            // The starvation-freedom stressor: longs front-loaded in
            // bursts so every short request arrives BEHIND a block-scale
            // prefill — the head-of-line case Medha calls out.
            "adversarial" => Some(TraceSpec {
                name: "adversarial",
                seed: 0xBAD_F00D,
                n_requests: 12,
                arrival: Arrival::Bursty { burst: 4, gap_ticks: 16 },
                mix: LengthMix {
                    long_fraction: 0.34,
                    long_chunk_tokens: 1,
                    short_max_new: (1, 3),
                    long_max_new: (6, 10),
                },
                prefix_hit_rate: 0.25,
                corpus_size: 2,
                class_weights: [0.6, 0.4, 0.0],
                follow_up_rate: 0.0,
                follow_up_gap_ticks: 0,
            }),
            // Steady open-loop traffic, mostly short, occasional long.
            "poisson" => Some(TraceSpec {
                name: "poisson",
                seed: 0x9035_07,
                n_requests: 16,
                arrival: Arrival::Poisson { mean_gap_ticks: 4.0 },
                mix: LengthMix {
                    long_fraction: 0.125,
                    long_chunk_tokens: 2,
                    short_max_new: (2, 5),
                    long_max_new: (6, 12),
                },
                prefix_hit_rate: 0.4,
                corpus_size: 3,
                class_weights: [0.4, 0.5, 0.1],
                follow_up_rate: 0.0,
                follow_up_gap_ticks: 0,
            }),
            // Closed bursts with idle valleys — exercises advance_to's
            // clock jumps and queue drain between bursts.
            "bursty" => Some(TraceSpec {
                name: "bursty",
                seed: 0xB0257,
                n_requests: 12,
                arrival: Arrival::Bursty { burst: 3, gap_ticks: 32 },
                mix: LengthMix {
                    long_fraction: 0.25,
                    long_chunk_tokens: 2,
                    short_max_new: (1, 4),
                    long_max_new: (4, 8),
                },
                prefix_hit_rate: 0.3,
                corpus_size: 2,
                class_weights: [0.3, 0.5, 0.2],
                follow_up_rate: 0.0,
                follow_up_gap_ticks: 0,
            }),
            // Soak scale: thousands of base requests with multi-turn
            // follow-up arrivals riding a shared corpus. Sized for the
            // closed-loop goodput sweep ([`sweep_closed_loop`]) and for
            // exercising the adaptive decode chooser against a realistic
            // warm/cold mix — NOT for the CI smoke gate.
            "soak" => Some(TraceSpec {
                name: "soak",
                seed: 0x50AC_50AC,
                n_requests: 2000,
                arrival: Arrival::Poisson { mean_gap_ticks: 1.5 },
                mix: LengthMix {
                    long_fraction: 0.05,
                    long_chunk_tokens: 2,
                    short_max_new: (1, 4),
                    long_max_new: (4, 8),
                },
                prefix_hit_rate: 0.3,
                corpus_size: 8,
                class_weights: [0.4, 0.5, 0.1],
                follow_up_rate: 0.35,
                follow_up_gap_ticks: 24,
            }),
            _ => None,
        }
    }

    /// The named specs [`TraceSpec::by_name`] accepts.
    pub const NAMES: [&'static str; 5] = ["smoke", "adversarial", "poisson", "bursty", "soak"];
}

/// One trace entry: the fully built request and the scheduler tick it
/// arrives on.
#[derive(Debug, Clone)]
pub struct TracedRequest {
    pub at_tick: u64,
    pub req: Request,
    /// Whether this request replays a shared-corpus pair (every replay
    /// after the pair's first use hits the prefix store when enabled).
    pub shares_corpus: bool,
    /// Whether this arrival is a follow-up turn: a replay of an earlier
    /// request's exact (doc, query) pair after a think-time gap. Always a
    /// prefix-store hit once its parent has run, so follow-up traffic
    /// reads as warm to the adaptive decode chooser.
    pub follow_up: bool,
}

/// A materialized workload: tick-stamped requests in arrival order.
#[derive(Debug, Clone)]
pub struct Trace {
    pub spec: TraceSpec,
    pub arrivals: Vec<TracedRequest>,
}

impl Trace {
    /// Requests flagged long (block-scale chunking) by the generator.
    pub fn n_long(&self) -> usize {
        self.arrivals
            .iter()
            .filter(|a| a.req.opts.chunk_tokens.is_some())
            .count()
    }
}

fn random_tokens(rng: &mut Rng, n: usize, vocab: usize) -> Vec<i32> {
    // Avoid token 0 so traces never collide with the all-zero docs some
    // unit tests use as sentinels.
    (0..n).map(|_| rng.range(1, vocab as i64) as i32).collect()
}

/// Expand a [`TraceSpec`] into a concrete [`Trace`] for `cfg`'s geometry.
/// Pure function of (cfg, spec): same inputs ⇒ same trace, independent of
/// driver, wall clock or call site.
pub fn generate(cfg: &Config, spec: &TraceSpec) -> Result<Trace> {
    if spec.n_requests == 0 {
        bail!("trace '{}' generates no requests", spec.name);
    }
    spec.long_fraction_checked()?;
    let mut rng = Rng::new(spec.seed);
    let vocab = cfg.model.vocab_size;
    let doc_len = cfg.apb.doc_len();
    let query_len = cfg.apb.query_len;
    // Shared corpus: pre-generated (doc, query) pairs that hit-intended
    // requests replay wholesale (the prefix digest covers doc AND query).
    let corpus: Vec<(Vec<i32>, Vec<i32>)> = (0..spec.corpus_size.max(1))
        .map(|_| {
            (random_tokens(&mut rng, doc_len, vocab), random_tokens(&mut rng, query_len, vocab))
        })
        .collect();
    let mut arrivals = Vec::with_capacity(spec.n_requests);
    let mut at_tick = 0u64;
    for i in 0..spec.n_requests {
        // Arrival clock.
        if i > 0 {
            match spec.arrival {
                Arrival::Poisson { mean_gap_ticks } => {
                    let u = rng.f64().max(1e-12);
                    at_tick += (-u.ln() * mean_gap_ticks).round() as u64;
                }
                Arrival::Bursty { burst, gap_ticks } => {
                    if i % burst.max(1) == 0 {
                        at_tick += gap_ticks;
                    }
                }
            }
        }
        // Length mix: heavy tail via the chunking + decode-budget axes.
        let long = rng.f64() < spec.mix.long_fraction;
        let (class, opts, max_new, doc, query, shares_corpus) = if long {
            let opts = crate::config::ApbOptions {
                chunk_tokens: Some(spec.mix.long_chunk_tokens.max(1)),
                ..Default::default()
            };
            let (lo, hi) = spec.mix.long_max_new;
            let max_new = rng.range(lo as i64, hi as i64 + 1) as usize;
            (
                Class::Batch,
                opts,
                max_new,
                random_tokens(&mut rng, doc_len, vocab),
                random_tokens(&mut rng, query_len, vocab),
                false,
            )
        } else {
            let class = Class::ALL[rng.choice_weighted(&spec.class_weights)];
            let (lo, hi) = spec.mix.short_max_new;
            let max_new = rng.range(lo as i64, hi as i64 + 1) as usize;
            let shares = rng.f64() < spec.prefix_hit_rate;
            let (doc, query) = if shares {
                corpus[rng.below(corpus.len() as u64) as usize].clone()
            } else {
                (random_tokens(&mut rng, doc_len, vocab), random_tokens(&mut rng, query_len, vocab))
            };
            (class, crate::config::ApbOptions::default(), max_new, doc, query, shares)
        };
        arrivals.push(TracedRequest {
            at_tick,
            req: Request { id: i as u64, doc, query, max_new, opts, class },
            shares_corpus,
            follow_up: false,
        });
    }
    // Multi-turn follow-ups: replay a short request's exact (doc, query)
    // pair after a think-time gap. The digest covers the whole pair, so
    // every follow-up hits the prefix store once its parent has run —
    // this is the warm traffic the adaptive decode chooser keys on.
    if spec.follow_up_rate > 0.0 {
        let mut follow_ups = Vec::new();
        for a in &arrivals {
            if a.req.opts.chunk_tokens.is_none() && rng.f64() < spec.follow_up_rate {
                follow_ups.push(TracedRequest {
                    at_tick: a.at_tick + spec.follow_up_gap_ticks,
                    req: a.req.clone(),
                    shares_corpus: a.shares_corpus,
                    follow_up: true,
                });
            }
        }
        arrivals.extend(follow_ups);
        // Stable sort keeps parent-before-follow-up at equal ticks; ids
        // are reassigned so every submission stays unique.
        arrivals.sort_by_key(|a| a.at_tick);
        for (i, a) in arrivals.iter_mut().enumerate() {
            a.req.id = i as u64;
        }
    }
    Ok(Trace { spec: spec.clone(), arrivals })
}

impl TraceSpec {
    fn long_fraction_checked(&self) -> Result<f64> {
        let f = self.mix.long_fraction;
        if !(0.0..=1.0).contains(&f) {
            bail!("trace '{}': long_fraction {f} outside [0, 1]", self.name);
        }
        Ok(f)
    }
}

/// Drive a [`Trace`] through a scheduler to completion: submit each
/// request on its arrival tick, `step` the scheduler in between, and jump
/// the clock over idle gaps with `advance_to` (so aging and SLO
/// accounting see the gap without burning a step per empty tick). A full
/// admission queue defers the submission to a later tick instead of
/// dropping it — open-loop arrival with blocking backpressure, kept
/// deterministic. Returns how many requests completed.
pub fn run_trace(sched: &mut Scheduler<'_>, trace: &Trace) -> Result<usize> {
    let before = sched.completed.len();
    let mut next = 0usize;
    loop {
        while next < trace.arrivals.len() && trace.arrivals[next].at_tick <= sched.tick() {
            match sched.submit(trace.arrivals[next].req.clone()) {
                Ok(()) => next += 1,
                // Queue full: leave the arrival pending and let the
                // scheduler drain a tick first.
                Err(_) => break,
            }
        }
        let progressed = sched.step()?;
        if !progressed {
            if next < trace.arrivals.len() {
                sched.advance_to(trace.arrivals[next].at_tick);
            } else {
                break;
            }
        }
    }
    Ok(sched.completed.len() - before)
}

/// Closed-loop replay: ignore the trace's arrival clock and instead hold
/// the multiprogramming level at `concurrency` — submit the next request
/// the moment the number of outstanding requests (queued + resident +
/// parked) drops below the level, and never idle while work remains.
/// This is the load-generator dual of [`run_trace`]'s open loop: latency
/// vs goodput as a function of offered concurrency rather than of an
/// arrival process. Deterministic for a fixed (trace, level). Returns how
/// many requests completed.
pub fn run_trace_closed_loop(
    sched: &mut Scheduler<'_>,
    trace: &Trace,
    concurrency: usize,
) -> Result<usize> {
    if concurrency == 0 {
        bail!("closed-loop replay needs concurrency >= 1");
    }
    let before = sched.completed.len();
    let mut next = 0usize;
    loop {
        while next < trace.arrivals.len()
            && sched.queued() + sched.resident() + sched.parked_count() < concurrency
        {
            match sched.submit(trace.arrivals[next].req.clone()) {
                Ok(()) => next += 1,
                // Admission queue smaller than the level: let it drain.
                Err(_) => break,
            }
        }
        let progressed = sched.step()?;
        if !progressed {
            if next >= trace.arrivals.len() && sched.queued() == 0 {
                break;
            }
            // The window is full of parked work waiting on the clock
            // (aging, starvation budgets): advance it one tick so the
            // loop can make progress instead of spinning.
            sched.advance_to(sched.tick() + 1);
        }
    }
    Ok(sched.completed.len() - before)
}

/// One operating point from [`sweep_closed_loop`]: the trace replayed at
/// a fixed multiprogramming level.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    /// Multiprogramming level held by the closed loop.
    pub concurrency: usize,
    pub completed: usize,
    /// Scheduler tick when the last request retired.
    pub final_tick: u64,
    pub total_tokens: usize,
    /// Decode tokens delivered per scheduler tick at this level — the
    /// goodput axis of the latency/goodput curve.
    pub goodput_tok_per_tick: f64,
    pub ttft_ticks_p50: f64,
    pub ttft_ticks_p95: f64,
    /// Fraction of requests that met their class TTFT SLO.
    pub slo_fraction: f64,
}

/// Replay `trace` closed-loop at each multiprogramming level in `levels`,
/// each on a fresh [`Scheduler`] over the same cluster (prefix-store
/// warmth carries across points, as it would across the phases of a real
/// soak), and report the latency/goodput curve. Levels run in the given
/// order; the whole sweep is deterministic for a fixed (cluster state,
/// trace, levels).
pub fn sweep_closed_loop(
    cluster: &crate::coordinator::Cluster,
    max_queue: usize,
    trace: &Trace,
    levels: &[usize],
) -> Result<Vec<SweepPoint>> {
    let mut points = Vec::with_capacity(levels.len());
    for &level in levels {
        let mut sched = Scheduler::new(cluster, max_queue);
        let completed = run_trace_closed_loop(&mut sched, trace, level)?;
        let m = sched.metrics();
        let slo_met: usize = m.per_class.iter().map(|c| c.slo_met).sum();
        points.push(SweepPoint {
            concurrency: level,
            completed,
            final_tick: sched.tick(),
            total_tokens: m.total_tokens,
            goodput_tok_per_tick: m.total_tokens as f64 / sched.tick().max(1) as f64,
            ttft_ticks_p50: m.ttft_ticks.p50,
            ttft_ticks_p95: m.ttft_ticks.p95,
            slo_fraction: if m.n_requests == 0 {
                1.0
            } else {
                slo_met as f64 / m.n_requests as f64
            },
        });
    }
    Ok(points)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> Config {
        Config::sim_tiny()
    }

    #[test]
    fn named_specs_generate_deterministically() {
        for name in TraceSpec::NAMES {
            let spec = TraceSpec::by_name(name).expect("named spec");
            let a = generate(&cfg(), &spec).unwrap();
            let b = generate(&cfg(), &spec).unwrap();
            // Follow-up turns ride on top of the base request count.
            assert!(a.arrivals.len() >= spec.n_requests);
            if spec.follow_up_rate == 0.0 {
                assert_eq!(a.arrivals.len(), spec.n_requests);
            }
            assert_eq!(a.arrivals.len(), b.arrivals.len());
            for (x, y) in a.arrivals.iter().zip(&b.arrivals) {
                assert_eq!(x.at_tick, y.at_tick, "{name}: arrival clock diverged");
                assert_eq!(x.req.doc, y.req.doc, "{name}: doc tokens diverged");
                assert_eq!(x.req.query, y.req.query);
                assert_eq!(x.req.max_new, y.req.max_new);
                assert_eq!(x.req.class, y.req.class);
                assert_eq!(x.req.opts.chunk_tokens, y.req.opts.chunk_tokens);
            }
        }
        assert!(TraceSpec::by_name("nope").is_none());
    }

    #[test]
    fn arrivals_are_monotone_and_sized_to_config() {
        let c = cfg();
        for name in TraceSpec::NAMES {
            let trace = generate(&c, &TraceSpec::by_name(name).unwrap()).unwrap();
            let mut last = 0;
            for a in &trace.arrivals {
                assert!(a.at_tick >= last, "{name}: arrivals out of order");
                last = a.at_tick;
                assert_eq!(a.req.doc.len(), c.apb.doc_len());
                assert_eq!(a.req.query.len(), c.apb.query_len);
                assert!(a.req.doc.iter().all(|&t| t > 0 && (t as usize) < c.model.vocab_size));
            }
        }
    }

    #[test]
    fn long_requests_are_batch_class_with_fine_chunks() {
        let trace =
            generate(&cfg(), &TraceSpec::by_name("adversarial").unwrap()).unwrap();
        assert!(trace.n_long() >= 1, "adversarial trace needs a block-scale prefill");
        for a in &trace.arrivals {
            if let Some(ct) = a.req.opts.chunk_tokens {
                assert_eq!(a.req.class, Class::Batch);
                assert!(ct <= 2, "long requests chunk finely (got {ct})");
                assert!(!a.shares_corpus, "longs never ride the corpus");
            }
        }
    }

    #[test]
    fn corpus_sharing_reuses_exact_pairs() {
        let spec = TraceSpec {
            prefix_hit_rate: 1.0,
            ..TraceSpec::by_name("smoke").unwrap()
        };
        let trace = generate(&cfg(), &spec).unwrap();
        let sharers: Vec<&TracedRequest> =
            trace.arrivals.iter().filter(|a| a.shares_corpus).collect();
        assert!(sharers.len() >= 2, "hit rate 1.0 must produce sharers");
        // Sharers replay corpus pairs wholesale: the number of DISTINCT
        // (doc, query) pairs among them is bounded by the corpus size —
        // the digest covers both doc and query, so anything less than
        // verbatim reuse would never hit the store.
        let mut distinct: Vec<(&[i32], &[i32])> = Vec::new();
        for s in &sharers {
            let pair = (s.req.doc.as_slice(), s.req.query.as_slice());
            if !distinct.contains(&pair) {
                distinct.push(pair);
            }
        }
        assert!(
            distinct.len() <= spec.corpus_size,
            "{} distinct pairs among sharers exceeds corpus of {}",
            distinct.len(),
            spec.corpus_size
        );
    }

    #[test]
    fn soak_spec_is_soak_scale_with_follow_up_turns() {
        let spec = TraceSpec::by_name("soak").expect("soak spec");
        assert!(spec.n_requests >= 1000, "soak means thousands of requests");
        let trace = generate(&cfg(), &spec).unwrap();
        assert!(trace.arrivals.len() > spec.n_requests, "soak must emit follow-up turns");
        // Ids stay unique and dense after the follow-up merge, and the
        // clock stays monotone.
        let mut last = 0;
        for (i, a) in trace.arrivals.iter().enumerate() {
            assert_eq!(a.req.id, i as u64, "ids must be reassigned after sorting");
            assert!(a.at_tick >= last);
            last = a.at_tick;
        }
        // Every follow-up replays an EARLIER arrival's exact pair —
        // that verbatim reuse is what makes it a prefix-store hit and
        // hence warm traffic for the decode chooser.
        let n_follow = trace.arrivals.iter().filter(|a| a.follow_up).count();
        assert!(n_follow > 0);
        for f in trace.arrivals.iter().filter(|a| a.follow_up) {
            assert!(f.req.opts.chunk_tokens.is_none(), "only shorts get follow-ups");
            let parent = trace.arrivals.iter().any(|p| {
                !p.follow_up
                    && p.at_tick + spec.follow_up_gap_ticks == f.at_tick
                    && p.req.doc == f.req.doc
                    && p.req.query == f.req.query
            });
            assert!(parent, "follow-up without a matching earlier arrival");
        }
    }

    #[test]
    fn closed_loop_sweep_reports_latency_and_goodput() {
        use crate::coordinator::{Cluster, Driver};
        // Small trace with follow-ups so the sweep sees warm turns.
        let spec = TraceSpec {
            follow_up_rate: 0.5,
            follow_up_gap_ticks: 8,
            ..TraceSpec::by_name("smoke").unwrap()
        };
        let c = cfg();
        let trace = generate(&c, &spec).unwrap();
        let cluster = Cluster::start_with(&c, Driver::Sequential).expect("cluster");
        let points = sweep_closed_loop(&cluster, 64, &trace, &[1, 3]).expect("sweep");
        assert_eq!(points.len(), 2);
        for p in &points {
            assert_eq!(p.completed, trace.arrivals.len(), "closed loop must drain the trace");
            assert!(p.final_tick > 0);
            assert!(p.goodput_tok_per_tick > 0.0);
            assert!(p.ttft_ticks_p95 >= p.ttft_ticks_p50);
            assert!((0.0..=1.0).contains(&p.slo_fraction));
        }
        // Determinism: replaying the same level on a fresh cluster gives
        // the same operating point.
        let cluster2 = Cluster::start_with(&c, Driver::Sequential).expect("cluster");
        let again = sweep_closed_loop(&cluster2, 64, &trace, &[1]).expect("sweep");
        assert_eq!(again[0].final_tick, points[0].final_tick);
        assert_eq!(again[0].total_tokens, points[0].total_tokens);
    }

    #[test]
    fn seed_changes_trace() {
        let base = TraceSpec::by_name("poisson").unwrap();
        let reseeded = TraceSpec { seed: base.seed + 1, ..base.clone() };
        let a = generate(&cfg(), &base).unwrap();
        let b = generate(&cfg(), &reseeded).unwrap();
        let differs = a
            .arrivals
            .iter()
            .zip(&b.arrivals)
            .any(|(x, y)| x.req.doc != y.req.doc || x.at_tick != y.at_tick);
        assert!(differs, "reseeding must change the trace");
    }
}
