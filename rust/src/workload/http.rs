//! Closed-loop HTTP load driver: replay a [`Trace`](super::Trace) against
//! a running `apb serve --http` front door instead of an in-process
//! scheduler.
//!
//! This is the network dual of [`super::run_trace_closed_loop`]: `N`
//! worker threads each hold one keep-alive [`HttpClient`] connection and
//! race down the shared arrival list, so the offered multiprogramming
//! level equals the worker count. The trace's arrival clock is ignored —
//! closed-loop drivers measure the server's capacity, not the arrival
//! process. Per response the driver verifies the streaming contract the
//! tier-1 suite pins bit-exactly: every `token` event line arrives in its
//! own HTTP chunk, indices are dense, and the terminal `done` event's
//! `tokens` array equals the streamed sequence. `429 Too Many Requests`
//! is retried after the server's `Retry-After` hint (capped so smoke runs
//! stay fast) and counted, feeding the CI gate that wants backpressure
//! *observed*, not assumed.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::coordinator::scheduler::Request;
use crate::http::client::{HttpClient, HttpResponse};
use crate::util::json::{Json, JsonWriter};

use super::Trace;

/// Aggregate outcome of one closed-loop HTTP replay.
#[derive(Debug, Clone, Default)]
pub struct HttpLoadReport {
    /// Requests taken off the trace (== trace length when all workers ran
    /// to completion).
    pub attempted: usize,
    /// Requests that streamed to a clean `done` event.
    pub completed: usize,
    /// Total `429 Too Many Requests` responses observed (each is retried
    /// until it clears or the retry budget runs out).
    pub rejected_429: usize,
    /// Requests dropped after exhausting the 429 retry budget.
    pub dropped: usize,
    /// Non-(200|429) responses and transport failures.
    pub errors: usize,
    /// Responses whose token events arrived in >= 2 distinct HTTP chunks —
    /// the "actually streamed" observable (chunk boundaries are preserved
    /// by [`HttpClient`]).
    pub multi_chunk: usize,
    /// Tokens summed over clean completions.
    pub total_tokens: usize,
    /// Completions whose streamed token sequence disagreed with the
    /// terminal `done.tokens` array (always 0 unless the server is broken).
    pub mismatches: usize,
}

/// Per-request attempts before a persistently-429ing request is dropped.
const MAX_429_RETRIES: usize = 200;

/// Serialize one trace request as a `/v1/generate` body.
pub fn generate_body(req: &Request) -> String {
    let mut w = JsonWriter::obj()
        .tokens_field("doc", &req.doc)
        .tokens_field("query", &req.query)
        .num_field("max_new", req.max_new as f64)
        .str_field("class", req.class.name());
    if let Some(ct) = req.opts.chunk_tokens {
        w = w.num_field("chunk_tokens", ct as f64);
    }
    if let Some(ps) = req.opts.pass_strategy {
        w = w.str_field("pass_strategy", ps.name());
    }
    w.close()
}

/// Outcome of decoding one streamed generate response.
struct StreamOutcome {
    tokens: Vec<i32>,
    token_chunks: usize,
    clean: bool,
    matched: bool,
}

/// Decode the NDJSON event stream out of a chunked response body.
fn decode_stream(resp: &HttpResponse) -> Result<StreamOutcome> {
    let mut streamed: Vec<i32> = Vec::new();
    let mut done_tokens: Option<Vec<i32>> = None;
    let mut token_chunks = 0usize;
    let mut clean = false;
    for chunk in &resp.chunks {
        let text = std::str::from_utf8(chunk).context("non-UTF-8 event chunk")?;
        let mut chunk_has_token = false;
        for line in text.lines().filter(|l| !l.trim().is_empty()) {
            let ev = Json::parse(line).with_context(|| format!("bad event line '{line}'"))?;
            match ev.req("event").ok().and_then(|e| e.as_str()) {
                Some("token") => {
                    let idx = ev.req("index").ok().and_then(|v| v.as_usize());
                    if idx != Some(streamed.len()) {
                        bail!("token index {idx:?}, expected {}", streamed.len());
                    }
                    let tok = ev
                        .req("token")
                        .ok()
                        .and_then(|v| v.as_i64())
                        .context("token event without token")?;
                    streamed.push(tok as i32);
                    chunk_has_token = true;
                }
                Some("done") => {
                    clean = ev.get("error").is_none();
                    done_tokens = ev.get("tokens").map(|t| {
                        t.as_arr()
                            .map(|a| a.iter().filter_map(|x| x.as_i64().map(|v| v as i32)).collect())
                            .unwrap_or_default()
                    });
                }
                other => bail!("unknown event {other:?}"),
            }
        }
        if chunk_has_token {
            token_chunks += 1;
        }
    }
    let matched = match &done_tokens {
        Some(toks) => *toks == streamed,
        None => false,
    };
    Ok(StreamOutcome { tokens: streamed, token_chunks, clean: clean && done_tokens.is_some(), matched })
}

/// Replay `trace` against `addr` with `concurrency` keep-alive worker
/// connections. Returns the merged report; transport errors surface in
/// [`HttpLoadReport::errors`] rather than aborting the other workers.
pub fn drive_http_trace(addr: &str, trace: &Trace, concurrency: usize) -> Result<HttpLoadReport> {
    if concurrency == 0 {
        bail!("closed-loop HTTP replay needs concurrency >= 1");
    }
    let bodies: Arc<Vec<String>> =
        Arc::new(trace.arrivals.iter().map(|a| generate_body(&a.req)).collect());
    let next = Arc::new(AtomicUsize::new(0));
    let addr = addr.to_string();
    let workers = concurrency.min(bodies.len()).max(1);
    let mut joins = Vec::with_capacity(workers);
    for _ in 0..workers {
        let bodies = Arc::clone(&bodies);
        let next = Arc::clone(&next);
        let addr = addr.clone();
        joins.push(thread::spawn(move || worker_main(&addr, &bodies, &next)));
    }
    let mut report = HttpLoadReport::default();
    for j in joins {
        let part = j.join().map_err(|_| anyhow::anyhow!("HTTP load worker panicked"))??;
        report.attempted += part.attempted;
        report.completed += part.completed;
        report.rejected_429 += part.rejected_429;
        report.dropped += part.dropped;
        report.errors += part.errors;
        report.multi_chunk += part.multi_chunk;
        report.total_tokens += part.total_tokens;
        report.mismatches += part.mismatches;
    }
    Ok(report)
}

fn worker_main(
    addr: &str,
    bodies: &[String],
    next: &AtomicUsize,
) -> Result<HttpLoadReport> {
    let mut report = HttpLoadReport::default();
    let mut client = HttpClient::connect(addr)?;
    loop {
        let i = next.fetch_add(1, Ordering::SeqCst);
        if i >= bodies.len() {
            return Ok(report);
        }
        report.attempted += 1;
        let mut attempts = 0usize;
        loop {
            let resp = match client.request("POST", "/v1/generate", Some(&bodies[i])) {
                Ok(r) => r,
                Err(_) => {
                    // Reconnect once (the server may have closed an idle
                    // keep-alive connection); a second failure is an error.
                    client = HttpClient::connect(addr)?;
                    match client.request("POST", "/v1/generate", Some(&bodies[i])) {
                        Ok(r) => r,
                        Err(_) => {
                            report.errors += 1;
                            break;
                        }
                    }
                }
            };
            match resp.status {
                429 => {
                    report.rejected_429 += 1;
                    attempts += 1;
                    if attempts > MAX_429_RETRIES {
                        report.dropped += 1;
                        break;
                    }
                    // Honor Retry-After, capped so smoke runs stay fast.
                    let hint_s: u64 = resp
                        .header("retry-after")
                        .and_then(|v| v.parse().ok())
                        .unwrap_or(0);
                    thread::sleep(Duration::from_millis((hint_s * 1000).clamp(10, 100)));
                }
                200 => {
                    match decode_stream(&resp) {
                        Ok(out) if out.clean => {
                            report.completed += 1;
                            report.total_tokens += out.tokens.len();
                            if out.token_chunks >= 2 {
                                report.multi_chunk += 1;
                            }
                            if !out.matched {
                                report.mismatches += 1;
                            }
                        }
                        _ => report.errors += 1,
                    }
                    break;
                }
                _ => {
                    report.errors += 1;
                    break;
                }
            }
        }
    }
}
