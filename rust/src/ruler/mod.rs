//! Synthetic benchmark suites (S13): the 13 RULER tasks and the 10
//! ∞Bench tasks the paper evaluates, in two forms:
//!
//! * `TaskProfile` — the mechanism-level description the accuracy oracle
//!   consumes (needle structure, cross-block dependency strength,
//!   distractor load, aggregation sensitivity) plus the paper's measured
//!   FULLATTN scores as calibration anchors (DESIGN.md §2);
//! * `gen_instance` — concrete token sequences with planted needles for
//!   the REAL tiny-model cluster runs (retention/attention-mass metrics).

pub mod tasks;

pub use tasks::{
    infbench_tasks, ruler_tasks, TaskKind, TaskProfile,
};

use crate::config::Config;
use crate::util::rng::Rng;

/// A concrete instance for the real small-model cluster.
#[derive(Debug, Clone)]
pub struct Instance {
    pub doc: Vec<i32>,
    pub query: Vec<i32>,
    /// Document positions that carry the needle (answer-relevant) tokens.
    pub needle_positions: Vec<usize>,
    /// The needle value tokens (what retrieval must surface).
    pub needle_values: Vec<i32>,
}

/// Generate a needle-in-a-haystack instance sized for `cfg`. The query
/// repeats the needle key so a (trained or untrained) model's attention
/// and the retaining heads have a concrete retrieval target.
pub fn gen_instance(cfg: &Config, kind: TaskKind, rng: &mut Rng) -> Instance {
    let a = &cfg.apb;
    let vocab = cfg.model.vocab_size as i64;
    let doc_len = a.doc_len();
    let mut doc: Vec<i32> = (0..doc_len)
        .map(|_| rng.range(1, vocab) as i32)
        .collect();

    let span = 4usize.min(a.query_len.max(2));
    let n_needles = match kind {
        TaskKind::SingleNiah | TaskKind::PassKey => 1,
        TaskKind::MultiKeyNiah { keys } => keys,
        TaskKind::MultiValueNiah | TaskKind::MultiQueryNiah => 4,
        TaskKind::VariableTracking { hops } => hops,
        TaskKind::Aggregation => 8,
        TaskKind::Qa { hops } => hops,
        _ => 1,
    };

    let mut needle_positions = Vec::new();
    let mut needle_values = Vec::new();
    let key: Vec<i32> = (0..span).map(|_| rng.range(1, vocab) as i32).collect();
    for ni in 0..n_needles {
        // Avoid the very first anchor region so retrieval is non-trivial.
        let pos = rng.range((a.anchor_len + span) as i64,
                            (doc_len - span) as i64) as usize;
        let value: Vec<i32> = (0..span).map(|_| rng.range(1, vocab) as i32).collect();
        for (i, (&k, &v)) in key.iter().zip(&value).enumerate() {
            // key token then value token interleaved marks the needle.
            doc[pos + i] = if ni == 0 { k } else { v };
        }
        for i in 0..span {
            needle_positions.push(pos + i);
        }
        needle_values.extend(value);
    }

    // Query embeds the needle key (truncated/padded to l_q).
    let mut query = vec![0i32; a.query_len];
    for (i, q) in query.iter_mut().enumerate() {
        *q = key[i % key.len()];
    }
    Instance { doc, query, needle_positions, needle_values }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ApbParams, ModelConfig};

    fn cfg() -> Config {
        Config::sim(
            "t",
            ModelConfig {
                vocab_size: 64,
                n_layers: 2,
                d_model: 32,
                n_heads: 4,
                n_kv_heads: 2,
                d_ff: 64,
                rope_theta: 1e4,
                rms_eps: 1e-5,
                retaining_hidden: 16,
            },
            ApbParams {
                n_hosts: 4,
                block_len: 32,
                anchor_len: 8,
                query_len: 4,
                passing_len: 8,
                max_new_tokens: 8,
                max_resident: 2,
                chunk_tokens: 16,
                prefix_cache: false,
            },
            0,
        )
    }

    #[test]
    fn instance_shapes_and_bounds() {
        let c = cfg();
        let mut rng = Rng::new(1);
        for kind in [TaskKind::SingleNiah, TaskKind::MultiKeyNiah { keys: 3 },
                     TaskKind::Aggregation] {
            let inst = gen_instance(&c, kind, &mut rng);
            assert_eq!(inst.doc.len(), c.apb.doc_len());
            assert_eq!(inst.query.len(), c.apb.query_len);
            assert!(!inst.needle_positions.is_empty());
            assert!(inst.needle_positions.iter().all(|&p| p < c.apb.doc_len()));
            assert!(inst.doc.iter().all(|&t| t >= 0 && (t as usize) < 64));
        }
    }

    #[test]
    fn instances_vary_with_seed() {
        let c = cfg();
        let a = gen_instance(&c, TaskKind::SingleNiah, &mut Rng::new(1));
        let b = gen_instance(&c, TaskKind::SingleNiah, &mut Rng::new(2));
        assert_ne!(a.doc, b.doc);
    }

    #[test]
    fn task_tables_complete() {
        assert_eq!(ruler_tasks().len(), 13);
        assert_eq!(infbench_tasks().len(), 10);
        for t in ruler_tasks().iter().chain(infbench_tasks().iter()) {
            assert!(t.base_acc.llama >= 0.0);
            assert!(t.base_acc.llama <= 100.0);
            assert!(t.out_tokens > 0);
        }
    }
}
