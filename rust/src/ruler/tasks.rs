//! Task profiles for RULER (13 tasks) and ∞Bench (the 10 tasks the paper
//! keeps). Each profile carries:
//!
//! * the paper's measured FULLATTN scores (Tables 1, 2 and 14) as the
//!   calibration anchors for the accuracy oracle — these are the paper's
//!   own numbers for exact attention, NOT ours; every approximate-method
//!   score is *derived* from the mechanism model in `oracle`;
//! * mechanism parameters: how much the task depends on cross-block
//!   context, how distractor-loaded it is (→ APB's denoising upside),
//!   how much it aggregates over the whole context, and how chained
//!   (multi-hop) it is (→ compression downside);
//! * an output-length profile for the speed model (Tables 9/12).

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TaskKind {
    SingleNiah,
    MultiKeyNiah { keys: usize },
    MultiValueNiah,
    MultiQueryNiah,
    VariableTracking { hops: usize },
    Aggregation,
    Qa { hops: usize },
    PassKey,
    KvRetrieval,
    Summarization,
    MultipleChoice,
    Dialogue,
    CodeDebug,
    MathFind,
}

/// Per-model FULLATTN anchors at 128K (paper Tables 1 and 2).
#[derive(Debug, Clone, Copy)]
pub struct BaseAcc {
    pub llama: f64,
    pub qwen: f64,
    pub yi: f64,
}

#[derive(Debug, Clone)]
pub struct TaskProfile {
    pub id: &'static str,
    pub suite: &'static str, // "ruler" | "infbench"
    pub kind: TaskKind,
    pub base_acc: BaseAcc,
    /// FULLATTN (Llama-3-8B-1M) accuracy across {32K,64K,128K,256K,512K}
    /// (paper Table 14) — the length-decay anchor for Figure 4(a).
    pub length_curve: [f64; 5],
    /// Guessing floor (e.g. 25 for 4-way multiple choice).
    pub chance: f64,
    /// Mechanism parameters in [0, 1].
    pub cross_block: f64,
    pub distractor: f64,
    pub aggregation: f64,
    pub chain: f64,
    /// Average answer length (tokens) for the speed metric.
    pub out_tokens: usize,
}

pub const LENGTHS: [f64; 5] = [32768.0, 65536.0, 131072.0, 262144.0, 524288.0];

impl TaskProfile {
    /// FULLATTN accuracy at length `n` for the given model column:
    /// the Table 14 curve, rescaled so the 128K point matches the model's
    /// Table 1/2 anchor.
    pub fn base_at(&self, model: ModelCol, n: f64) -> f64 {
        let anchor_128k = self.length_curve[2].max(1e-9);
        let scale = self.base(model) / anchor_128k;
        (interp(&LENGTHS, &self.length_curve, n) * scale).clamp(0.0, 100.0)
    }

    pub fn base(&self, model: ModelCol) -> f64 {
        match model {
            ModelCol::Llama => self.base_acc.llama,
            ModelCol::Qwen => self.base_acc.qwen,
            ModelCol::Yi => self.base_acc.yi,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelCol {
    Llama,
    Qwen,
    Yi,
}

impl ModelCol {
    pub const ALL: [ModelCol; 3] = [ModelCol::Llama, ModelCol::Qwen, ModelCol::Yi];

    pub fn name(&self) -> &'static str {
        match self {
            ModelCol::Llama => "Llama-3.1-8B",
            ModelCol::Qwen => "Qwen-2.5-14B",
            ModelCol::Yi => "Yi-34B-200K",
        }
    }
}

fn interp(xs: &[f64; 5], ys: &[f64; 5], x: f64) -> f64 {
    if x <= xs[0] {
        return ys[0];
    }
    if x >= xs[4] {
        return ys[4];
    }
    for i in 0..4 {
        if x <= xs[i + 1] {
            let t = (x.ln() - xs[i].ln()) / (xs[i + 1].ln() - xs[i].ln());
            return ys[i] * (1.0 - t) + ys[i + 1] * t;
        }
    }
    ys[4]
}

macro_rules! task {
    ($id:literal, $suite:literal, $kind:expr, ($l:expr, $q:expr, $y:expr),
     $curve:expr, chance=$ch:expr,
     cross=$cr:expr, distr=$di:expr, agg=$ag:expr, chain=$chn:expr,
     out=$out:expr) => {
        TaskProfile {
            id: $id,
            suite: $suite,
            kind: $kind,
            base_acc: BaseAcc { llama: $l, qwen: $q, yi: $y },
            length_curve: $curve,
            chance: $ch,
            cross_block: $cr,
            distractor: $di,
            aggregation: $ag,
            chain: $chn,
            out_tokens: $out,
        }
    };
}

/// RULER: Table 2 anchors (128K) + Table 14 length curves.
pub fn ruler_tasks() -> Vec<TaskProfile> {
    use TaskKind::*;
    vec![
        task!("SG1", "ruler", SingleNiah, (99.40, 100.00, 100.00),
              [100.0, 100.0, 100.0, 100.0, 98.0], chance = 0.0,
              cross = 0.10, distr = 0.10, agg = 0.0, chain = 0.0, out = 32),
        task!("SG2", "ruler", SingleNiah, (99.80, 99.20, 100.00),
              [100.0, 100.0, 100.0, 100.0, 98.0], chance = 0.0,
              cross = 0.10, distr = 0.10, agg = 0.0, chain = 0.0, out = 32),
        task!("SG3", "ruler", SingleNiah, (99.60, 99.80, 99.60),
              [98.0, 98.0, 100.0, 96.0, 100.0], chance = 0.0,
              cross = 0.12, distr = 0.15, agg = 0.0, chain = 0.0, out = 32),
        task!("MK1", "ruler", MultiKeyNiah { keys: 3 }, (98.20, 94.20, 95.20),
              [100.0, 100.0, 98.0, 94.0, 94.0], chance = 0.0,
              cross = 0.20, distr = 0.50, agg = 0.0, chain = 0.0, out = 32),
        task!("MK2", "ruler", MultiKeyNiah { keys: 6 }, (87.60, 47.80, 76.00),
              [96.0, 98.0, 100.0, 97.2, 76.0], chance = 0.0,
              cross = 0.28, distr = 0.85, agg = 0.0, chain = 0.0, out = 32),
        task!("MK3", "ruler", MultiKeyNiah { keys: 9 }, (67.00, 27.20, 55.40),
              [82.0, 56.0, 36.0, 22.0, 10.0], chance = 0.0,
              cross = 0.32, distr = 1.00, agg = 0.0, chain = 0.0, out = 32),
        task!("MV", "ruler", MultiValueNiah, (94.65, 75.10, 92.10),
              [97.0, 99.0, 98.5, 92.5, 90.5], chance = 0.0,
              cross = 0.22, distr = 0.60, agg = 0.05, chain = 0.0, out = 48),
        task!("MQ", "ruler", MultiQueryNiah, (98.00, 94.60, 97.05),
              [98.5, 98.0, 95.5, 95.0, 96.0], chance = 0.0,
              cross = 0.20, distr = 0.40, agg = 0.05, chain = 0.0, out = 48),
        task!("VT", "ruler", VariableTracking { hops: 4 }, (60.98, 89.52, 85.56),
              [92.0, 84.4, 77.2, 64.0, 46.8], chance = 0.0,
              cross = 0.55, distr = 0.20, agg = 0.10, chain = 0.85, out = 48),
        task!("CWE", "ruler", Aggregation, (71.40, 93.88, 51.84),
              [40.2, 1.2, 0.4, 0.6, 0.6], chance = 0.0,
              cross = 0.20, distr = 0.10, agg = 1.00, chain = 0.0, out = 64),
        task!("FWE", "ruler", Aggregation, (72.20, 76.13, 84.27),
              [88.0, 78.7, 72.0, 76.7, 86.7], chance = 0.0,
              cross = 0.15, distr = 0.10, agg = 0.45, chain = 0.0, out = 48),
        task!("QA1", "ruler", Qa { hops: 1 }, (78.20, 63.20, 65.20),
              [82.0, 68.0, 68.0, 78.0, 70.0], chance = 5.0,
              cross = 0.45, distr = 0.30, agg = 0.10, chain = 0.25, out = 48),
        task!("QA2", "ruler", Qa { hops: 2 }, (41.60, 43.40, 50.00),
              [64.0, 54.0, 46.0, 44.0, 46.0], chance = 5.0,
              cross = 0.55, distr = 0.30, agg = 0.15, chain = 0.35, out = 48),
    ]
}

/// ∞Bench: Table 1 anchors. Length curves default to mildly decaying
/// (∞Bench has no controlled-length variant; only the 128K point is used
/// in the paper's tables).
pub fn infbench_tasks() -> Vec<TaskProfile> {
    use TaskKind::*;
    const FLAT: [f64; 5] = [105.0, 102.0, 100.0, 96.0, 90.0];
    vec![
        task!("R.PassKey", "infbench", PassKey, (100.00, 100.00, 100.00),
              FLAT, chance = 0.0,
              cross = 0.05, distr = 0.10, agg = 0.0, chain = 0.0, out = 16),
        task!("R.Number", "infbench", PassKey, (99.49, 100.00, 100.00),
              FLAT, chance = 0.0,
              cross = 0.05, distr = 0.12, agg = 0.0, chain = 0.0, out = 16),
        task!("R.KV", "infbench", KvRetrieval, (51.00, 17.80, 49.00),
              FLAT, chance = 0.0,
              cross = 0.30, distr = 1.00, agg = 0.0, chain = 0.0, out = 32),
        task!("E.Sum", "infbench", Summarization, (30.59, 27.80, 5.83),
              FLAT, chance = 5.0,
              cross = 0.25, distr = 0.05, agg = 0.80, chain = 0.0, out = 800),
        task!("E.QA", "infbench", Qa { hops: 2 }, (29.04, 10.40, 17.57),
              FLAT, chance = 2.0,
              cross = 0.45, distr = 0.25, agg = 0.15, chain = 0.30, out = 64),
        task!("E.MC", "infbench", MultipleChoice, (63.76, 52.84, 47.60),
              FLAT, chance = 25.0,
              cross = 0.45, distr = 0.35, agg = 0.10, chain = 0.15, out = 8),
        task!("E.Dia", "infbench", Dialogue, (11.00, 28.00, 2.00),
              FLAT, chance = 1.0,
              cross = 0.40, distr = 0.30, agg = 0.10, chain = 0.20, out = 32),
        task!("Z.QA", "infbench", Qa { hops: 2 }, (36.18, 10.21, 18.77),
              FLAT, chance = 2.0,
              cross = 0.45, distr = 0.25, agg = 0.15, chain = 0.30, out = 64),
        task!("C.Debug", "infbench", CodeDebug, (24.62, 38.07, 25.13),
              FLAT, chance = 12.5,
              cross = 0.35, distr = 0.45, agg = 0.15, chain = 0.20, out = 16),
        task!("M.Find", "infbench", MathFind, (28.82, 42.57, 28.00),
              FLAT, chance = 5.0,
              cross = 0.20, distr = 0.60, agg = 0.25, chain = 0.05, out = 16),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interp_endpoints_and_midpoints() {
        let t = &ruler_tasks()[5]; // MK3
        // 82 * 67/36 would exceed 100 -> clamped.
        assert_eq!(t.base_at(ModelCol::Llama, 32768.0), 100.0);
        // At 512K the rescale stays in range: 10 * 67/36.
        let v = t.base_at(ModelCol::Llama, 524288.0);
        assert!((v - 10.0 * 67.0 / 36.0).abs() < 1e-9);
        // Monotone decreasing task: midpoint between anchors.
        let mid = interp(&LENGTHS, &t.length_curve, 92681.9); // ~ sqrt(64K*128K)
        assert!(mid < 56.0 && mid > 36.0);
        // Clamped outside range.
        assert_eq!(interp(&LENGTHS, &t.length_curve, 1e9), 10.0);
        assert_eq!(interp(&LENGTHS, &t.length_curve, 1.0), 82.0);
    }

    #[test]
    fn model_columns_match_paper_anchors() {
        let tasks = ruler_tasks();
        let sg1 = &tasks[0];
        assert_eq!(sg1.base(ModelCol::Llama), 99.40);
        assert_eq!(sg1.base(ModelCol::Qwen), 100.00);
        let avg: f64 = tasks.iter().map(|t| t.base(ModelCol::Llama)).sum::<f64>()
            / tasks.len() as f64;
        // Paper Table 2: Llama FULLATTN average 82.20.
        assert!((avg - 82.20).abs() < 0.3, "avg {avg}");
    }

    #[test]
    fn infbench_average_matches_table1() {
        let tasks = infbench_tasks();
        let avg: f64 = tasks.iter().map(|t| t.base(ModelCol::Llama)).sum::<f64>()
            / tasks.len() as f64;
        // Paper Table 1: Llama FULLATTN average 47.45.
        assert!((avg - 47.45).abs() < 0.3, "avg {avg}");
    }
}
