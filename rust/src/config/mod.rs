//! Configuration mirrored from `python/compile/configs.py`, loaded from
//! `artifacts/<name>/manifest.json`. The python side is the source of
//! truth (shapes are baked into the HLO artifacts); rust re-derives and
//! cross-checks the derived quantities.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub vocab_size: usize,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_ff: usize,
    pub rope_theta: f64,
    pub rms_eps: f64,
    pub retaining_hidden: usize,
}

impl ModelConfig {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    pub fn gqa_groups(&self) -> usize {
        self.n_heads / self.n_kv_heads
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct ApbParams {
    pub n_hosts: usize,
    pub block_len: usize,   // l_b
    pub anchor_len: usize,  // l_a
    pub query_len: usize,   // l_q
    pub passing_len: usize, // l_p
    pub max_new_tokens: usize,
    /// Serving residency: KV-pool slots per host, i.e. how many sessions
    /// may hold their caches on the cluster simultaneously (continuous
    /// batching). 1 reproduces the paper's one-request-at-a-time setting.
    pub max_resident: usize,
    /// Chunked-prefill granularity: how many document tokens one
    /// `Cmd::PrefillChunk` step advances (per host, per layer phase). The
    /// scheduler interleaves resident sessions' decode ticks between chunk
    /// steps, so this bounds the head-of-line blocking a newly admitted
    /// long request can inflict (Medha-style stall-free serving). Chunking
    /// is bit-identical to one-shot prefill by construction (see
    /// `docs/ADR-002-chunked-prefill.md`); values `>= block_len` degenerate
    /// to one chunk per phase. Per-request override:
    /// [`ApbOptions::chunk_tokens`]. Must be >= 1.
    pub chunk_tokens: usize,
    /// Shared-prefix KV reuse (`docs/ADR-003-prefix-caching.md`): when
    /// `true`, every cold prefill freezes its document KV into the host
    /// pool's refcounted prefix store (keyed by a rank-symmetric content
    /// digest, see `kvcache::prefix_digest`), and a later request with the
    /// same digest skips the per-layer document pass entirely — its session
    /// attaches to the immutable `kvcache::SharedPrefix` entry and decodes
    /// over a `[shared | private]` KV view, bit-identical to a cold
    /// prefill. `false` (the default, and the pre-PR-5 behaviour) keeps
    /// every prefill cold. CLI: `apb serve --prefix-cache`.
    pub prefix_cache: bool,
}

impl ApbParams {
    pub fn l_aq(&self) -> usize {
        self.query_len + self.anchor_len
    }

    pub fn n_tot(&self) -> usize {
        self.l_aq() + self.block_len
    }

    pub fn pass_max(&self) -> usize {
        (self.n_hosts - 1) * self.passing_len
    }

    pub fn doc_len(&self) -> usize {
        self.n_hosts * self.block_len
    }

    pub fn cache_max(&self) -> usize {
        self.block_len + self.query_len + self.max_new_tokens
    }

    /// Per-slot KV rows a host's pool must reserve to serve `method`.
    /// The distributed modes (APB/Star/Ring) cap at [`ApbParams::cache_max`]
    /// — a host holds at most its local block (+ query prefix on ring host
    /// 0) plus the re-fed query chunk and decode tail. `Dense` concentrates
    /// the whole `[query | document]` sequence on host 0, so its slot must
    /// hold everything.
    pub fn cache_rows(&self, method: AttnMethod) -> usize {
        match method {
            AttnMethod::Dense => {
                2 * self.query_len + self.doc_len() + self.max_new_tokens
            }
            _ => self.cache_max(),
        }
    }

    /// Effective chunked-prefill granularity for one request: the
    /// per-request override when present, else the cluster default —
    /// clamped to >= 1 so a degenerate 0 can never stall the state machine.
    pub fn chunk_tokens_for(&self, opts: &ApbOptions) -> usize {
        opts.chunk_tokens.unwrap_or(self.chunk_tokens).max(1)
    }
}

/// Which attention method the executable cluster runs — the paper's
/// comparison set as *measured* cluster modes, not just analytic models.
///
/// Every mode executes end-to-end on [`crate::coordinator::Cluster`]
/// (prefill + decode on either backend), so comparisons report measured
/// communication rounds/bytes and exactness against the dense oracle. The
/// analytic twin is `attnsim::Method` (`impl From<AttnMethod>` in
/// `attnsim::walltime`); the two must agree on
/// [`AttnMethod::exact_attention`], which is asserted in tests. See
/// `docs/architecture.md` ("Method matrix") and
/// `docs/ADR-001-attn-methods.md` for the design rationale.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AttnMethod {
    /// The paper's method (Alg. 2 prefill): anchor block + compressed
    /// passing blocks AllGathered across hosts (`kv` comm label).
    Apb,
    /// Star Attention (Acharya et al. 2024): anchor block, no passing —
    /// zero prefill communication. Formerly the `use_passing: false`
    /// ablation toggle.
    StarAttn,
    /// Ring Attention / Context Parallelism (Yang et al. 2024): hosts
    /// rotate their full KV blocks around a ring (`ring` comm label) and
    /// merge partial attentions with the online-softmax identity — exact.
    RingAttn,
    /// Whole sequence on host 0 with plain causal attention: the exactness
    /// anchor every exact method must match. No communication.
    Dense,
}

impl AttnMethod {
    pub const ALL: [AttnMethod; 4] =
        [AttnMethod::Apb, AttnMethod::StarAttn, AttnMethod::RingAttn, AttnMethod::Dense];

    pub fn name(&self) -> &'static str {
        match self {
            AttnMethod::Apb => "APB",
            AttnMethod::StarAttn => "StarAttn",
            AttnMethod::RingAttn => "RingAttn",
            AttnMethod::Dense => "Dense",
        }
    }

    /// Parse a CLI spelling (`--method apb|star|ring|dense`).
    pub fn parse(s: &str) -> Result<AttnMethod> {
        match s.to_ascii_lowercase().as_str() {
            "apb" => Ok(AttnMethod::Apb),
            "star" | "starattn" => Ok(AttnMethod::StarAttn),
            "ring" | "ringattn" => Ok(AttnMethod::RingAttn),
            "dense" | "full" | "flash" => Ok(AttnMethod::Dense),
            other => bail!("unknown attention method '{other}' \
                            (expected apb|star|ring|dense)"),
        }
    }

    /// Does this method compute *exact* full causal attention? Exact
    /// methods must produce logits matching [`AttnMethod::Dense`] within
    /// float tolerance; the analytic `attnsim::Method::exact_attention`
    /// must agree (tested).
    pub fn exact_attention(&self) -> bool {
        matches!(self, AttnMethod::RingAttn | AttnMethod::Dense)
    }

    /// Does prefill AllGather compressed (K_c, V_c) passing blocks
    /// (the paper's §3.5 step, `kv` meter label)? Only APB does.
    pub fn passes_compressed_blocks(&self) -> bool {
        matches!(self, AttnMethod::Apb)
    }

    /// Does decode run the distributed per-host partial-attention +
    /// online-softmax-merge path (`att` meter label)? All methods except
    /// `Dense`, which decodes entirely on host 0.
    pub fn distributed_decode(&self) -> bool {
        !matches!(self, AttnMethod::Dense)
    }

    /// Meter labels this method's *prefill* can charge (see
    /// `cluster::Interconnect` label constants).
    pub fn prefill_comm_labels(&self) -> &'static [&'static str] {
        match self {
            AttnMethod::Apb => &["kv"],
            AttnMethod::RingAttn => &["ring"],
            AttnMethod::StarAttn | AttnMethod::Dense => &[],
        }
    }
}

/// How the distributed decode/append path moves attention state between
/// hosts (`docs/ADR-007-adaptive-decode.md`). Context Parallelism (Yang et
/// al., PAPERS.md) frames the choice: move the (large, context-sized) KV
/// toward the query, or move the (tiny, context-independent) query/partial
/// state toward the resident KV.
///
/// Both executable strategies are **bit-identical**: they feed the same
/// per-rank partials, reordered into rank order, through the same
/// `util::tensor::merge_partials` fold, so logits, KV pool bytes and every
/// non-decode comm label match exactly. Only the decode comm label differs
/// (`att` AllGather vs `qring` ring rotation).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PassStrategy {
    /// Gather per-host attention partials with one AllGather per layer per
    /// step (`att` label) — the pass-KV-shaped baseline path and the
    /// pre-ADR-007 behaviour.
    PassKv,
    /// Rotate per-host attention partials around the ring (`qring` label),
    /// one neighbour exchange per round, `n_hosts - 1` rounds per layer —
    /// per-round payload is O(batch x heads x head_dim), independent of
    /// context length.
    PassQ,
    /// Choose per session at decode time: `PassQ` when the session's KV is
    /// already resident from a warm start (prefix-store hit) or a prior
    /// turn (multi-turn append), else `PassKv`. The choice is made on the
    /// leader from rank-uniform state and shipped in the decode command, so
    /// every host resolves identically.
    Auto,
}

impl PassStrategy {
    pub const ALL: [PassStrategy; 3] =
        [PassStrategy::PassKv, PassStrategy::PassQ, PassStrategy::Auto];

    pub fn name(&self) -> &'static str {
        match self {
            PassStrategy::PassKv => "pass-kv",
            PassStrategy::PassQ => "pass-q",
            PassStrategy::Auto => "auto",
        }
    }

    /// Parse a CLI spelling (`--pass-strategy kv|q|auto`).
    pub fn parse(s: &str) -> Result<PassStrategy> {
        match s.to_ascii_lowercase().as_str() {
            "kv" | "pass-kv" | "passkv" | "gather" => Ok(PassStrategy::PassKv),
            "q" | "pass-q" | "passq" | "ring" => Ok(PassStrategy::PassQ),
            "auto" | "adaptive" => Ok(PassStrategy::Auto),
            other => bail!("unknown pass strategy '{other}' \
                            (expected kv|q|auto)"),
        }
    }

    /// Resolve `Auto` into a concrete executable strategy for one decode
    /// batch. `warm` is the rank-uniform chooser input: true when every
    /// session in the batch holds KV that was already resident before this
    /// request's tokens arrived (prefix-store hit or a completed earlier
    /// turn). Single-host rings have no rotation to win from, and `Dense`
    /// never reaches a distributed decode, so both resolve to `PassKv`.
    pub fn resolve(self, warm: bool, n_hosts: usize, method: AttnMethod) -> PassStrategy {
        if !method.distributed_decode() || n_hosts < 2 {
            return PassStrategy::PassKv;
        }
        match self {
            PassStrategy::Auto => {
                if warm {
                    PassStrategy::PassQ
                } else {
                    PassStrategy::PassKv
                }
            }
            fixed => fixed,
        }
    }
}

/// Which execution backend a config is bound to (see `runtime`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-Rust native engine: deterministic synthetic weights, no
    /// artifacts, always available.
    Sim,
    /// PJRT engine replaying AOT'd HLO artifacts (`pjrt` cargo feature).
    Pjrt,
}

impl BackendKind {
    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Sim => "sim",
            BackendKind::Pjrt => "pjrt",
        }
    }
}

#[derive(Debug, Clone)]
pub struct Config {
    pub name: String,
    pub seed: u64,
    pub model: ModelConfig,
    pub apb: ApbParams,
    /// Cluster-level attention method: sizes each host's KV pool
    /// (`ApbParams::cache_rows`) and is the default for sessions that start
    /// decoding without a prefill. Per-request overrides ride on
    /// [`ApbOptions::method`]; a request may only pick a method whose cache
    /// footprint fits the pool this config sized (checked at prefill).
    pub method: AttnMethod,
    /// Execution backend this config is bound to.
    pub backend: BackendKind,
    /// Artifact directory this config was loaded from (unused for `Sim`).
    pub dir: PathBuf,
    /// Full parsed manifest (artifacts, weights, golden sections);
    /// `Json::Null` for `Sim` configs.
    pub manifest: Json,
    /// Kernel-pool threads per `SimEngine`. 0 (the default) defers to
    /// `runtime::sim::resolve_sim_threads`: the `APB_SIM_THREADS` env var,
    /// else `available_parallelism / n_hosts`. Set explicitly in tests that
    /// must not race on the process environment.
    pub sim_threads: usize,
    /// Pin the sim backend to its scalar reference kernels (serial, no
    /// tiling) — the retired pre-ADR-005 hot path, kept as the baseline the
    /// runtime bench compares the tiled/pooled kernels against.
    /// Bit-identical to the default; only wall time differs.
    pub sim_scalar: bool,
    /// Cluster-default decode pass strategy (`docs/ADR-007-adaptive-decode.md`):
    /// how distributed decode moves attention partials between hosts.
    /// Per-request override rides on [`ApbOptions::pass_strategy`]. The
    /// default, [`PassStrategy::PassKv`], is the pre-ADR-007 gather path,
    /// so existing configs and manifests are behaviour-preserving.
    /// CLI: `apb serve --pass-strategy kv|q|auto`.
    pub pass_strategy: PassStrategy,
}

fn u(v: &Json, key: &str) -> Result<usize> {
    v.req(key)?
        .as_usize()
        .with_context(|| format!("field '{key}' not a usize"))
}

fn f(v: &Json, key: &str) -> Result<f64> {
    v.req(key)?
        .as_f64()
        .with_context(|| format!("field '{key}' not a number"))
}

impl Config {
    /// Load `dir/manifest.json` and validate derived fields against the
    /// python-side record.
    pub fn load(dir: &Path) -> Result<Config> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let manifest = Json::parse(&text).context("parsing manifest.json")?;
        let cfg_j = manifest.req("config")?;
        let m = cfg_j.req("model")?;
        let a = cfg_j.req("apb")?;
        let model = ModelConfig {
            vocab_size: u(m, "vocab_size")?,
            n_layers: u(m, "n_layers")?,
            d_model: u(m, "d_model")?,
            n_heads: u(m, "n_heads")?,
            n_kv_heads: u(m, "n_kv_heads")?,
            d_ff: u(m, "d_ff")?,
            rope_theta: f(m, "rope_theta")?,
            rms_eps: f(m, "rms_eps")?,
            retaining_hidden: u(m, "retaining_hidden")?,
        };
        let apb = ApbParams {
            n_hosts: u(a, "n_hosts")?,
            block_len: u(a, "block_len")?,
            anchor_len: u(a, "anchor_len")?,
            query_len: u(a, "query_len")?,
            passing_len: u(a, "passing_len")?,
            max_new_tokens: u(a, "max_new_tokens")?,
            // Older manifests predate serving slots; one resident session
            // (the paper's setting) keeps their artifact shapes valid.
            max_resident: match a.get("max_resident") {
                Some(v) => v.as_usize().context("field 'max_resident' not a usize")?,
                None => 1,
            },
            // Older manifests predate chunked prefill; defaulting to the
            // LARGEST per-host row count of any method (Dense host 0's
            // whole [query | doc] sequence) makes every machine degenerate
            // to one chunk per phase — the exact pre-chunking call
            // sequence, which is all the PJRT artifact set supports.
            chunk_tokens: match a.get("chunk_tokens") {
                Some(v) => v.as_usize().context("field 'chunk_tokens' not a usize")?,
                None => u(a, "query_len")? + u(a, "n_hosts")? * u(a, "block_len")?,
            },
            // Older manifests predate the prefix store; cold-only prefill
            // (the paper's setting) keeps them byte-for-byte compatible.
            prefix_cache: match a.get("prefix_cache") {
                Some(v) => v.as_bool().context("field 'prefix_cache' not a bool")?,
                None => false,
            },
        };
        // Older manifests predate the adaptive decode path; the gather
        // (pass-KV) strategy is the pre-ADR-007 behaviour they were built
        // against.
        let pass_strategy = match a.get("pass_strategy") {
            Some(v) => PassStrategy::parse(
                v.as_str().context("field 'pass_strategy' not a string")?,
            )?,
            None => PassStrategy::PassKv,
        };
        if apb.max_resident == 0 {
            bail!("max_resident must be >= 1");
        }
        if apb.chunk_tokens == 0 {
            bail!("chunk_tokens must be >= 1");
        }
        if model.d_model % model.n_heads != 0 {
            bail!("d_model {} not divisible by n_heads {}", model.d_model, model.n_heads);
        }
        if model.n_heads % model.n_kv_heads != 0 {
            bail!("n_heads {} not divisible by n_kv_heads {}", model.n_heads,
                  model.n_kv_heads);
        }
        // Cross-check python's derived block against our re-derivation.
        let derived = cfg_j.req("derived")?;
        for (key, want) in [
            ("l_aq", apb.l_aq()),
            ("n_tot", apb.n_tot()),
            ("pass_max", apb.pass_max()),
            ("doc_len", apb.doc_len()),
            ("cache_max", apb.cache_max()),
            ("head_dim", model.head_dim()),
            ("gqa_groups", model.gqa_groups()),
        ] {
            let got = u(derived, key)?;
            if got != want {
                bail!("derived field '{key}': python {got} != rust {want}");
            }
        }
        let name = cfg_j
            .req("name")?
            .as_str()
            .context("config name")?
            .to_string();
        let seed = cfg_j.req("seed")?.as_i64().context("seed")? as u64;
        Ok(Config {
            name,
            seed,
            model,
            apb,
            method: AttnMethod::Apb,
            backend: BackendKind::Pjrt,
            dir: dir.to_path_buf(),
            manifest,
            sim_threads: 0,
            sim_scalar: false,
            pass_strategy,
        })
    }

    /// Build a SimEngine-backed config directly (no artifacts on disk).
    pub fn sim(name: &str, model: ModelConfig, apb: ApbParams, seed: u64) -> Config {
        Config {
            name: name.to_string(),
            seed,
            model,
            apb,
            method: AttnMethod::Apb,
            backend: BackendKind::Sim,
            dir: PathBuf::new(),
            manifest: Json::Null,
            sim_threads: 0,
            sim_scalar: false,
            pass_strategy: PassStrategy::PassKv,
        }
    }

    /// Pin the sim kernel pool to exactly `n` threads (see
    /// [`Config::sim_threads`]); `n = 1` forces the tiled kernels serial.
    pub fn with_sim_threads(mut self, n: usize) -> Config {
        self.sim_threads = n;
        self
    }

    /// Pin the sim backend to the scalar reference kernels (see
    /// [`Config::sim_scalar`]) — the bench baseline and proptest oracle.
    pub fn with_sim_scalar(mut self, on: bool) -> Config {
        self.sim_scalar = on;
        self
    }

    /// Toggle shared-prefix KV reuse ([`ApbParams::prefix_cache`]) on this
    /// config. Enabling it never changes any request's logits, KV bytes or
    /// decode comm — only whether a repeated document's prefill recomputes
    /// (see `docs/ADR-003-prefix-caching.md`).
    pub fn with_prefix_cache(mut self, on: bool) -> Config {
        self.apb.prefix_cache = on;
        self
    }

    /// Set the cluster-default decode pass strategy (see
    /// [`Config::pass_strategy`]). Any value yields bit-identical logits,
    /// KV bytes and pool accounting — only the decode comm label (and, for
    /// `Auto`, the per-session choice) changes.
    pub fn with_pass_strategy(mut self, s: PassStrategy) -> Config {
        self.pass_strategy = s;
        self
    }

    /// Rebind the cluster to another attention method (pool sizing + the
    /// default method of prefill-less sessions). Weights depend only on
    /// `seed`, so two clusters differing only in method are numerically
    /// comparable — that is how the exactness tests pit RingAttn against
    /// Dense.
    pub fn with_method(mut self, method: AttnMethod) -> Config {
        self.method = method;
        self
    }

    /// The default self-contained tiny config: small enough that a full
    /// prefill+decode runs in milliseconds on one CPU core, large enough
    /// that every APB mechanism (anchor, passing blocks, compressor,
    /// online-softmax merge) is exercised across 3 hosts.
    pub fn sim_tiny() -> Config {
        Config::sim(
            "sim-tiny",
            ModelConfig {
                vocab_size: 128,
                n_layers: 2,
                d_model: 32,
                n_heads: 4,
                n_kv_heads: 2,
                d_ff: 64,
                rope_theta: 1e4,
                rms_eps: 1e-5,
                retaining_hidden: 16,
            },
            ApbParams {
                n_hosts: 3,
                block_len: 32,
                anchor_len: 8,
                query_len: 4,
                passing_len: 8,
                max_new_tokens: 8,
                max_resident: 4,
                // Half a block per chunk step: the default sim config
                // exercises the chunked machine (C = 2) in every test.
                chunk_tokens: 16,
                // Prefix caching is opt-in (Config::with_prefix_cache /
                // `apb serve --prefix-cache`): the default keeps every
                // tier-1 invariant test on the cold path it was written for.
                prefix_cache: false,
            },
            1234,
        )
    }
}

/// Per-request options: the attention method plus the APB ablation toggles
/// — rust mirror of `model.ApbOptions` (paper Table 3). The pre-`AttnMethod`
/// `use_passing: bool` spelling (and its deprecated shims) is gone:
/// `use_passing: false` is `method: AttnMethod::StarAttn`, and the python
/// mirror speaks the same method strings.
///
/// The ablation toggles (`use_anchor`, `retaining_compressor`,
/// `embed_query`) only apply to the anchor/compressor methods
/// (`Apb`/`StarAttn`); the exact baselines (`RingAttn`/`Dense`) run plain
/// causal attention and ignore them.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ApbOptions {
    /// Which cluster mode serves this request (paper "A"+"P" structure:
    /// `Apb` = anchor+passing, `StarAttn` = anchor only, plus the exact
    /// baselines).
    pub method: AttnMethod,
    pub use_anchor: bool,
    pub retaining_compressor: bool, // false => random selector "Rd."
    pub embed_query: bool,
    pub rd_seed: u64,
    /// Record the compressor's per-layer/per-head retained index sets in
    /// `PrefillReport.retained` (retention-recall experiments, §3.4).
    /// Off by default: the serving path would otherwise keep
    /// O(layers × kv_heads × l_p) of dead weight alive per completed
    /// request.
    pub record_retained: bool,
    /// Per-request chunked-prefill granularity override (`None` = the
    /// cluster's [`ApbParams::chunk_tokens`]). Any value yields bit-identical
    /// logits/KV/comm — it only changes how finely the prefill state machine
    /// is sliced between scheduler ticks.
    pub chunk_tokens: Option<usize>,
    /// Per-request decode pass strategy override (`None` = the cluster's
    /// [`Config::pass_strategy`]). Deliberately EXCLUDED from
    /// `kvcache::prefix_digest` (a decode-side knob, like `max_new`): a
    /// pass-Q session shares prefix entries with a pass-KV one because
    /// their prefill output is identical.
    pub pass_strategy: Option<PassStrategy>,
}

impl Default for ApbOptions {
    fn default() -> Self {
        ApbOptions {
            method: AttnMethod::Apb,
            use_anchor: true,
            retaining_compressor: true,
            embed_query: true,
            rd_seed: 1234,
            record_retained: false,
            chunk_tokens: None,
            pass_strategy: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn apb_params_derived() {
        let a = ApbParams {
            n_hosts: 4,
            block_len: 256,
            anchor_len: 32,
            query_len: 16,
            passing_len: 32,
            max_new_tokens: 64,
            max_resident: 2,
            chunk_tokens: 64,
            prefix_cache: false,
        };
        assert_eq!(a.l_aq(), 48);
        assert_eq!(a.n_tot(), 304);
        assert_eq!(a.pass_max(), 96);
        assert_eq!(a.doc_len(), 1024);
        assert_eq!(a.cache_max(), 336);
    }

    #[test]
    fn chunk_tokens_resolution() {
        let c = Config::sim_tiny();
        let a = &c.apb;
        assert!(a.chunk_tokens >= 1 && a.chunk_tokens < a.block_len,
                "sim-tiny must exercise the chunked machine by default");
        // No override: the cluster default wins.
        assert_eq!(a.chunk_tokens_for(&ApbOptions::default()), a.chunk_tokens);
        // Per-request override wins, clamped to >= 1.
        let o = ApbOptions { chunk_tokens: Some(5), ..Default::default() };
        assert_eq!(a.chunk_tokens_for(&o), 5);
        let zero = ApbOptions { chunk_tokens: Some(0), ..Default::default() };
        assert_eq!(a.chunk_tokens_for(&zero), 1, "0 clamps to 1, never stalls");
        // Oversized chunks are fine: they degenerate to one chunk per phase.
        let big = ApbOptions { chunk_tokens: Some(10 * a.doc_len()), ..Default::default() };
        assert_eq!(a.chunk_tokens_for(&big), 10 * a.doc_len());
    }

    #[test]
    fn prefix_cache_defaults_off_and_toggles() {
        let c = Config::sim_tiny();
        assert!(!c.apb.prefix_cache, "cold-only prefill is the seed default");
        let warm = c.clone().with_prefix_cache(true);
        assert!(warm.apb.prefix_cache);
        // Toggling the cache must not disturb anything numeric.
        assert_eq!(warm.seed, c.seed);
        assert_eq!(warm.method, c.method);
        assert!(!warm.with_prefix_cache(false).apb.prefix_cache);
    }

    #[test]
    fn sim_tiny_is_consistent() {
        let c = Config::sim_tiny();
        assert_eq!(c.backend, BackendKind::Sim);
        assert!(c.apb.max_resident >= 2, "serving config must allow residency overlap");
        assert_eq!(c.model.d_model % c.model.n_heads, 0);
        assert_eq!(c.model.n_heads % c.model.n_kv_heads, 0);
        assert!(c.apb.passing_len <= c.apb.block_len);
        assert!(c.apb.anchor_len + c.apb.query_len <= c.apb.block_len);
        assert_eq!(c.apb.doc_len(), c.apb.n_hosts * c.apb.block_len);
    }

    #[test]
    fn attn_method_parse_and_properties() {
        assert_eq!(AttnMethod::parse("apb").unwrap(), AttnMethod::Apb);
        assert_eq!(AttnMethod::parse("Star").unwrap(), AttnMethod::StarAttn);
        assert_eq!(AttnMethod::parse("ringattn").unwrap(), AttnMethod::RingAttn);
        assert_eq!(AttnMethod::parse("dense").unwrap(), AttnMethod::Dense);
        assert!(AttnMethod::parse("ulysses").is_err());
        // Exactness/communication structure of the four modes.
        assert!(AttnMethod::Dense.exact_attention());
        assert!(AttnMethod::RingAttn.exact_attention());
        assert!(!AttnMethod::Apb.exact_attention());
        assert!(!AttnMethod::StarAttn.exact_attention());
        assert!(AttnMethod::Apb.passes_compressed_blocks());
        assert!(!AttnMethod::StarAttn.passes_compressed_blocks());
        assert!(!AttnMethod::Dense.distributed_decode());
        for m in AttnMethod::ALL {
            if m != AttnMethod::Dense {
                assert!(m.distributed_decode(), "{} decodes distributed", m.name());
            }
        }
        assert_eq!(AttnMethod::Apb.prefill_comm_labels(), ["kv"]);
        assert_eq!(AttnMethod::RingAttn.prefill_comm_labels(), ["ring"]);
        assert!(AttnMethod::StarAttn.prefill_comm_labels().is_empty());
    }

    #[test]
    fn cache_rows_per_method() {
        let c = Config::sim_tiny();
        let a = &c.apb;
        for m in [AttnMethod::Apb, AttnMethod::StarAttn, AttnMethod::RingAttn] {
            assert_eq!(a.cache_rows(m), a.cache_max());
            // Ring host 0 holds [query | block 0] — must fit the slot.
            assert!(a.query_len + a.block_len <= a.cache_rows(m));
        }
        // Dense host 0 holds the whole sequence + re-fed chunk + decode tail.
        assert_eq!(
            a.cache_rows(AttnMethod::Dense),
            2 * a.query_len + a.doc_len() + a.max_new_tokens
        );
        assert!(a.cache_rows(AttnMethod::Dense) > a.cache_max());
        // with_method rebinds without touching the model.
        let d = c.clone().with_method(AttnMethod::Dense);
        assert_eq!(d.method, AttnMethod::Dense);
        assert_eq!(d.seed, c.seed);
    }

    #[test]
    fn pass_strategy_parse_resolve_and_default() {
        assert_eq!(PassStrategy::parse("kv").unwrap(), PassStrategy::PassKv);
        assert_eq!(PassStrategy::parse("pass-q").unwrap(), PassStrategy::PassQ);
        assert_eq!(PassStrategy::parse("Auto").unwrap(), PassStrategy::Auto);
        assert!(PassStrategy::parse("teleport").is_err());
        // The cluster default is the pre-ADR-007 gather path.
        let c = Config::sim_tiny();
        assert_eq!(c.pass_strategy, PassStrategy::PassKv);
        let q = c.clone().with_pass_strategy(PassStrategy::PassQ);
        assert_eq!(q.pass_strategy, PassStrategy::PassQ);
        assert_eq!(q.seed, c.seed, "strategy never perturbs the model");
        assert_eq!(ApbOptions::default().pass_strategy, None);
        // Fixed strategies resolve to themselves on a distributed decode...
        for warm in [false, true] {
            assert_eq!(PassStrategy::PassKv.resolve(warm, 3, AttnMethod::Apb),
                       PassStrategy::PassKv);
            assert_eq!(PassStrategy::PassQ.resolve(warm, 3, AttnMethod::RingAttn),
                       PassStrategy::PassQ);
        }
        // ...Auto picks by warmth (the prefix-hit / multi-turn signal)...
        assert_eq!(PassStrategy::Auto.resolve(true, 3, AttnMethod::Apb),
                   PassStrategy::PassQ);
        assert_eq!(PassStrategy::Auto.resolve(false, 3, AttnMethod::Apb),
                   PassStrategy::PassKv);
        // ...and Dense / single-host always degenerate to the gather path.
        for s in PassStrategy::ALL {
            assert_eq!(s.resolve(true, 3, AttnMethod::Dense), PassStrategy::PassKv);
            assert_eq!(s.resolve(true, 1, AttnMethod::Apb), PassStrategy::PassKv);
        }
    }

    #[test]
    fn model_config_derived() {
        let m = ModelConfig {
            vocab_size: 512,
            n_layers: 4,
            d_model: 128,
            n_heads: 4,
            n_kv_heads: 2,
            d_ff: 256,
            rope_theta: 1e4,
            rms_eps: 1e-5,
            retaining_hidden: 64,
        };
        assert_eq!(m.head_dim(), 32);
        assert_eq!(m.gqa_groups(), 2);
    }
}
