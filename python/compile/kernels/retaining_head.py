"""L1 — Locret-style retaining-head compressor as a Pallas kernel (§3.4).

The compressor C scores every local KV unit; the coordinator keeps the
top-l_p per kv-head and AllGathers them as the compressed context block
B^C. Per kv-head features are [mean-of-group(Q), K, V] (3*hd), scored by a
small gelu MLP — the "retaining heads" of Locret (paper Appendix B.1),
trained at build time by train_retaining.py.

Grid = (kv_heads, token_tiles); each program runs the two matmuls for one
(kv-head, token-tile) block so the MLP weights stay resident in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _rh_body(feat_ref, w1_ref, b1_ref, w2_ref, b2_ref, out_ref):
    """feat_ref: [1, bn, 3*hd]; w1: [3*hd, r]; w2: [r, 1]; out: [1, bn]."""
    x = feat_ref[0].astype(jnp.float32)
    h = jnp.dot(x, w1_ref[...], preferred_element_type=jnp.float32)
    h = h + b1_ref[...]
    c = float(np.sqrt(2.0 / np.pi))
    h = 0.5 * h * (1.0 + jnp.tanh(c * (h + 0.044715 * h * h * h)))
    s = jnp.dot(h, w2_ref[...], preferred_element_type=jnp.float32)
    out_ref[0] = s[:, 0] + b2_ref[0]


def retaining_scores(feat, w1, b1, w2, b2, *, bn: int = 128,
                     interpret: bool = True):
    """Score KV units. feat: [n, kh, 3*hd] -> scores [n, kh] (f32)."""
    n, kh, f = feat.shape
    r = w1.shape[1]
    bn = min(bn, max(16, n))
    pad = (-n) % bn
    feat_h = jnp.transpose(feat, (1, 0, 2))            # [kh, n, f]
    if pad:
        feat_h = jnp.pad(feat_h, ((0, 0), (0, pad), (0, 0)))
    n_pad = feat_h.shape[1]

    out = pl.pallas_call(
        _rh_body,
        grid=(kh, n_pad // bn),
        in_specs=[
            pl.BlockSpec((1, bn, f), lambda h, t: (h, t, 0)),
            pl.BlockSpec((f, r), lambda h, t: (0, 0)),
            pl.BlockSpec((r,), lambda h, t: (0,)),
            pl.BlockSpec((r, 1), lambda h, t: (0, 0)),
            pl.BlockSpec((1,), lambda h, t: (0,)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda h, t: (h, t)),
        out_shape=jax.ShapeDtypeStruct((kh, n_pad), jnp.float32),
        interpret=interpret,
    )(feat_h, w1.astype(jnp.float32), b1.astype(jnp.float32),
      w2.astype(jnp.float32), b2.astype(jnp.float32))
    return jnp.transpose(out, (1, 0))[:n]


def build_features(q, k, v, q_query=None):
    """Assemble per-kv-head compressor features from projected Q/K/V.

    q: [n, h, hd]; k, v: [n, kh, hd] -> feat [n, kh, 3*hd + 2] where the
    query component is the mean over each GQA group (the information the
    paper's R sees: "[Q, K, V] as input").

    The last two features are query-similarity statistics (max and mean of
    q_query·k_i over the embedded-query rows). In the paper this
    query-awareness reaches the compressor implicitly: the query is
    embedded at the front of the anchor block (§3.3) so a *trained*
    backbone's local hidden states are query-conditioned by layer 1. Our
    substitute backbone is random-initialized (DESIGN.md §2), so the
    conditioning is surfaced as an explicit feature — the "Q" ablation
    still works because removing the embedded query zeroes these rows.

    q_query: [w, h, hd] (the anchor's query rows) or None -> zeros.
    """
    n, h, hd = q.shape
    kh = k.shape[1]
    g = h // kh
    q_grp = q.reshape(n, kh, g, hd).mean(axis=2)
    if q_query is None:
        sim_feat = jnp.zeros((n, kh, 2), q.dtype)
    else:
        w = q_query.shape[0]
        qq = q_query.reshape(w, kh, g, hd).mean(axis=2).astype(jnp.float32)
        s = jnp.einsum("wjd,njd->njw", qq, k.astype(jnp.float32))
        s = s / jnp.sqrt(jnp.asarray(hd, jnp.float32))
        sim_feat = jnp.stack([s.max(axis=-1), s.mean(axis=-1)],
                             axis=-1).astype(q.dtype)
    return jnp.concatenate([q_grp, k, v, sim_feat], axis=-1)


def top_lp_select(scores, k, v, l_p: int):
    """Keep the top-l_p KV units per kv-head, in ascending position order
    (preserves RoPE'd key order inside the passing block).

    scores: [n, kh]; k, v: [n, kh, hd] -> (k_c, v_c, idx): [l_p, kh, hd] x2,
    idx [l_p, kh] (i32 positions into the local block).
    """
    n, kh = scores.shape
    _, top_idx = jax.lax.top_k(scores.T, l_p)          # [kh, l_p]
    top_idx = jnp.sort(top_idx, axis=-1)
    kt = jnp.transpose(k, (1, 0, 2))                   # [kh, n, hd]
    vt = jnp.transpose(v, (1, 0, 2))
    k_c = jnp.take_along_axis(kt, top_idx[:, :, None], axis=1)
    v_c = jnp.take_along_axis(vt, top_idx[:, :, None], axis=1)
    return (jnp.transpose(k_c, (1, 0, 2)), jnp.transpose(v_c, (1, 0, 2)),
            jnp.transpose(top_idx, (1, 0)).astype(jnp.int32))
