"""L1 Pallas kernels (interpret=True) + pure-jnp oracles."""

from . import ref  # noqa: F401
from .apb_attention import (  # noqa: F401
    apb_attention,
    causal_attention,
    decode_attention,
)
from .retaining_head import (  # noqa: F401
    build_features,
    retaining_scores,
    top_lp_select,
)
