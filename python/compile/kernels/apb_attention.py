"""L1 — APB's modified FlashAttention as a Pallas kernel.

The paper implements its computation stage (§3.6) as a FLASHATTN-2 CUDA
kernel "with only the attention mask changed". This is the TPU/Pallas
re-think (DESIGN.md §7):

  * grid = (query_heads, query_tiles): one program per (head, q-tile) —
    the threadblock of the CUDA version;
  * the q tile is staged HBM→VMEM by its BlockSpec (shared-memory staging);
  * the kernel sweeps KV tiles with `lax.fori_loop`, carrying the online
    softmax state (m, l, acc) — the register accumulators of FLASHATTN;
  * tiles use MXU-shaped (block, head_dim) matmuls in f32;
  * the APB visibility mask over [anchor | passing | local] is evaluated
    per tile from global row/col iotas; `n_anchor` and `pass_len` arrive
    as runtime scalars so one compiled kernel serves every host.

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls, so the kernel is lowered to plain HLO (see /opt/xla-example).
Correctness is pinned against kernels/ref.py.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

NEG_INF = float(np.finfo(np.float32).min)


def _flash_body(params_ref, q_ref, k_ref, v_ref, o_ref, lse_ref, *,
                mask_fn: Callable, scale: float, bq: int, bk: int,
                nk_pad: int, nq_valid: int):
    """Shared online-softmax flash attention body.

    q_ref:  [1, bq, hd]   (this program's query tile, one head)
    k_ref:  [1, nk_pad, hd] (full padded key sequence, this head's kv head)
    v_ref:  [1, nk_pad, hd]
    params_ref: [P] i32 runtime scalars forwarded to mask_fn
    o_ref:  [1, bq, hd]; lse_ref: [1, bq]
    """
    qi = pl.program_id(1)
    hd = q_ref.shape[-1]
    q = q_ref[0].astype(jnp.float32) * scale           # [bq, hd]
    qg = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)
    params = params_ref[...]

    n_tiles = nk_pad // bk

    def tile_step(t, carry):
        m, l, acc = carry
        start = t * bk
        k = k_ref[0, pl.dslice(start, bk), :].astype(jnp.float32)
        v = v_ref[0, pl.dslice(start, bk), :].astype(jnp.float32)
        kg = start + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
        mask = mask_fn(qg, kg, params) & (qg < nq_valid)  # [bq, bk]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        m_safe = jnp.where(m_new > NEG_INF / 2, m_new, 0.0)
        p = jnp.where(mask, jnp.exp(s - m_safe[:, None]), 0.0)
        corr = jnp.where(m > NEG_INF / 2, jnp.exp(m - m_safe), 0.0)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc0 = jnp.zeros((bq, hd), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_tiles, tile_step, (m0, l0, acc0))

    l_safe = jnp.where(l > 0, l, 1.0)
    o_ref[0] = (acc / l_safe[:, None]).astype(o_ref.dtype)
    m_safe = jnp.where(m > NEG_INF / 2, m, 0.0)
    lse_ref[0] = jnp.where(l > 0, m_safe + jnp.log(l_safe), NEG_INF)


def _pad_axis(x, axis, mult):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _run_flash(q, k, v, params, mask_fn, *, bq, bk, interpret=True):
    """Launch the flash body over a (heads, q-tiles) grid.

    q: [nq, h, hd]; k/v: [nk, kh, hd]; params: i32 [P].
    Returns out [nq, h, hd] and lse [nq, h].
    """
    nq, h, hd = q.shape
    nk, kh, _ = k.shape
    g = h // kh
    scale = 1.0 / float(np.sqrt(hd))
    in_dtype = q.dtype

    # Head-major layouts; pad seq dims to tile multiples (kernel masks).
    qh = _pad_axis(jnp.transpose(q, (1, 0, 2)), 1, bq)      # [h, nq_pad, hd]
    kh_ = _pad_axis(jnp.transpose(k, (1, 0, 2)), 1, bk)     # [kh, nk_pad, hd]
    vh = _pad_axis(jnp.transpose(v, (1, 0, 2)), 1, bk)
    nq_pad, nk_pad = qh.shape[1], kh_.shape[1]

    grid = (h, nq_pad // bq)
    body = functools.partial(
        _flash_body, mask_fn=mask_fn, scale=scale, bq=bq, bk=bk,
        nk_pad=nk_pad, nq_valid=nq)
    out, lse = pl.pallas_call(
        body,
        grid=grid,
        in_specs=[
            pl.BlockSpec(params.shape, lambda hh, qi: (0,) * params.ndim),
            pl.BlockSpec((1, bq, hd), lambda hh, qi: (hh, qi, 0)),
            pl.BlockSpec((1, nk_pad, hd), lambda hh, qi: (hh // g, 0, 0)),
            pl.BlockSpec((1, nk_pad, hd), lambda hh, qi: (hh // g, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, hd), lambda hh, qi: (hh, qi, 0)),
            pl.BlockSpec((1, bq), lambda hh, qi: (hh, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((h, nq_pad, hd), in_dtype),
            jax.ShapeDtypeStruct((h, nq_pad), jnp.float32),
        ],
        interpret=interpret,
    )(params, qh, kh_, vh)
    out = jnp.transpose(out, (1, 0, 2))[:nq]
    lse = jnp.transpose(lse, (1, 0))[:nq]
    return out, lse


def apb_attention(q, k, v, n_anchor, pass_len, *, l_aq: int, pass_max: int,
                  bq: int = 128, bk: int = 128, interpret: bool = True):
    """APB prefill attention (paper Eq. 2).

    q: [l_aq + l_b, h, hd] — [anchor | local] queries
    k, v: [l_aq + pass_max + l_b, kh, hd] — [anchor | passing(pad) | local]
    n_anchor: i32 scalar in {0, l_aq}; pass_len: i32 scalar in [0, pass_max]

    Setting l_aq=0, pass_max=0 degenerates to plain causal FlashAttention —
    that is the FLASHATTN baseline / H=1 fallback mode (paper Limitations).
    Returns (out [nq, h, hd], lse [nq, h]).
    """
    nq = q.shape[0]
    l_b = nq - l_aq
    bq = min(bq, max(16, nq))
    bk = min(bk, max(16, k.shape[0]))

    def mask_fn(qg, kg, params):
        n_anc, p_len = params[0], params[1]
        is_anchor_q = qg < l_aq
        k_anchor = kg < l_aq
        k_passing = (kg >= l_aq) & (kg < l_aq + pass_max)
        k_local = (kg >= l_aq + pass_max) & (kg < l_aq + pass_max + l_b)
        anchor_vis = k_anchor & (kg <= qg)
        local_vis = (
            (k_anchor & (kg < n_anc))
            | (k_passing & ((kg - l_aq) < p_len))
            | (k_local & ((kg - l_aq - pass_max) <= (qg - l_aq)))
        )
        return jnp.where(is_anchor_q, anchor_vis, local_vis)

    params = jnp.stack([jnp.asarray(n_anchor, jnp.int32),
                        jnp.asarray(pass_len, jnp.int32)])
    return _run_flash(q, k, v, params, mask_fn, bq=bq, bk=bk,
                      interpret=interpret)


def causal_attention(q, k, v, *, bq: int = 128, bk: int = 128,
                     interpret: bool = True):
    """Plain causal FlashAttention — the FLASHATTN baseline path."""
    zero = jnp.zeros((), jnp.int32)
    return apb_attention(q, k, v, zero, zero, l_aq=0, pass_max=0,
                         bq=bq, bk=bk, interpret=interpret)


def decode_attention(q, k_cache, v_cache, cache_len, self_causal, *,
                     bq: int = 128, bk: int = 128, interpret: bool = True):
    """Per-host decode attention with LSE output (Algorithm 3 lines 3–8).

    q: [n, h, hd] chunk of new-token queries (n = l_q for the query pass,
    n = 1 for token-by-token decoding); k_cache/v_cache: [cmax, kh, hd]
    padded cache. self_causal=1 on the last host where the chunk's own KV
    has already been appended (cache_len includes it).
    """
    n = q.shape[0]
    bq = min(bq, max(8, n))
    bk = min(bk, max(16, k_cache.shape[0]))

    def mask_fn(qg, kg, params):
        c_len, sc = params[0], params[1]
        visible = c_len - sc * (n - 1 - qg)
        return kg < visible

    params = jnp.stack([jnp.asarray(cache_len, jnp.int32),
                        jnp.asarray(self_causal, jnp.int32)])
    return _run_flash(q, k_cache, v_cache, params, mask_fn, bq=bq, bk=bk,
                      interpret=interpret)
