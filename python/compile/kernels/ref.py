"""Pure-jnp correctness oracles for every Pallas kernel (L1).

These are deliberately naive O(n^2) dense implementations — the source of
truth the kernels (and, transitively, the whole rust stack through golden
files) are validated against.

Mask semantics (paper Eq. 2 / Figure 2) for the APB prefill layout.

Queries:  [ anchor (l_aq rows) | local (l_b rows) ]
Keys:     [ anchor (l_aq) | passing (pass_max, padded) | local (l_b) ]

  anchor query i (< l_aq):  sees anchor keys j <= i   (causal in anchor)
  local  query i (>= l_aq): sees anchor keys j < n_anchor,
                            passing keys with offset < pass_len,
                            local keys causally (j_local <= i_local)

`n_anchor` is 0 on host 1 (no anchor block) and l_aq elsewhere; when 0 the
anchor rows still self-attend causally so their (discarded) outputs stay
finite, but their keys are invisible to local queries.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def apb_mask(l_aq: int, pass_max: int, l_b: int, n_anchor, pass_len):
    """Boolean [nq, nk] visibility mask for the APB prefill attention.

    nq = l_aq + l_b ; nk = l_aq + pass_max + l_b.
    `n_anchor` / `pass_len` may be python ints or traced scalars.
    """
    nq = l_aq + l_b
    nk = l_aq + pass_max + l_b
    qi = jnp.arange(nq)[:, None]            # [nq, 1]
    kj = jnp.arange(nk)[None, :]            # [1, nk]

    is_anchor_q = qi < l_aq
    k_anchor = kj < l_aq
    k_passing = (kj >= l_aq) & (kj < l_aq + pass_max)
    k_local = kj >= l_aq + pass_max

    # Anchor queries: strictly causal inside the anchor segment.
    anchor_vis = k_anchor & (kj <= qi)
    # Local queries: full visibility of the valid anchor + valid passing
    # prefix, causal within the local segment.
    local_vis = (
        (k_anchor & (kj < n_anchor))
        | (k_passing & ((kj - l_aq) < pass_len))
        | (k_local & ((kj - l_aq - pass_max) <= (qi - l_aq)))
    )
    return jnp.where(is_anchor_q, anchor_vis, local_vis)


def attention_ref(q, k, v, mask, scale=None):
    """Dense masked attention. q:[nq,h,hd] k/v:[nk,kh,hd] mask:[nq,nk].

    GQA: query head i uses kv head i // (h // kh). Returns [nq,h,hd] and
    the log-sum-exp [nq,h] (base-e, matching online softmax accumulators).
    """
    nq, h, hd = q.shape
    nk, kh, _ = k.shape
    g = h // kh
    if scale is None:
        scale = 1.0 / np.sqrt(hd)
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    kv_idx = jnp.arange(h) // g
    kh_exp = kf[:, kv_idx, :]               # [nk, h, hd]
    vh_exp = vf[:, kv_idx, :]
    scores = jnp.einsum("qhd,khd->hqk", qf, kh_exp) * scale
    neg = jnp.finfo(jnp.float32).min
    scores = jnp.where(mask[None, :, :], scores, neg)
    m = jnp.max(scores, axis=-1, keepdims=True)
    # Rows with no visible keys: keep them finite (output 0, lse -inf).
    m_safe = jnp.where(m > neg / 2, m, 0.0)
    e = jnp.where(mask[None, :, :], jnp.exp(scores - m_safe), 0.0)
    l = jnp.sum(e, axis=-1)                 # [h, nq]
    out = jnp.einsum("hqk,khd->qhd", e, vh_exp)
    l_safe = jnp.where(l > 0, l, 1.0)
    out = out / l_safe.T[:, :, None]
    lse = jnp.where(l > 0, m_safe[..., 0] + jnp.log(l_safe), -jnp.inf)
    return out, lse.T                       # [nq,h,hd], [nq,h]


def apb_attention_ref(q, k, v, n_anchor, pass_len, l_aq, pass_max):
    """Oracle for the APB prefill kernel."""
    nq = q.shape[0]
    l_b = nq - l_aq
    mask = apb_mask(l_aq, pass_max, l_b, n_anchor, pass_len)
    return attention_ref(q, k, v, mask)


def decode_attention_ref(q, k_cache, v_cache, cache_len, self_causal):
    """Oracle for the decode kernel: a chunk of n new queries against a
    padded per-host cache.

    q:[n,h,hd]; k_cache/v_cache:[cmax,kh,hd]. If self_causal=1 the chunk's
    own KV has already been appended, so cache_len counts it and row i sees
    j < cache_len - (n-1-i). Otherwise every row sees j < cache_len.
    """
    n = q.shape[0]
    cmax = k_cache.shape[0]
    qi = jnp.arange(n)[:, None]
    kj = jnp.arange(cmax)[None, :]
    visible_len = cache_len - self_causal * (n - 1 - qi)
    mask = kj < visible_len
    return attention_ref(q, k_cache, v_cache, mask)


def merge_partials_ref(outs, lses):
    """Online-softmax merge of per-host partial attention (Algorithm 3).

    outs: [H][n,h,hd] partial numerator-normalized outputs
    lses: [H][n,h]    log-sum-exp of each partial
    Returns the exact softmax over the union of all hosts' keys.
    """
    outs = jnp.stack(outs)                  # [H,n,h,hd]
    lses = jnp.stack(lses)                  # [H,n,h]
    m = jnp.max(lses, axis=0)               # [n,h]
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    w = jnp.exp(lses - m_safe[None])        # [H,n,h]
    w = jnp.where(jnp.isfinite(lses), w, 0.0)
    denom = jnp.sum(w, axis=0)              # [n,h]
    denom_safe = jnp.where(denom > 0, denom, 1.0)
    merged = jnp.sum(outs * w[..., None], axis=0) / denom_safe[..., None]
    lse = jnp.where(denom > 0, m_safe + jnp.log(denom_safe), -jnp.inf)
    return merged, lse


def retaining_head_ref(feat, w1, b1, w2, b2):
    """Oracle for the Locret-style retaining head.

    feat:[n,kh,3*hd] -> gelu(feat @ w1 + b1) @ w2 + b2 -> scores [n,kh].
    """
    x = feat.astype(jnp.float32)
    h = jnp.dot(x, w1) + b1
    h = 0.5 * h * (1.0 + jnp.tanh(np.sqrt(2.0 / np.pi) * (h + 0.044715 * h**3)))
    s = jnp.dot(h, w2) + b2
    return s[..., 0]


def causal_mask(n: int):
    """Plain causal mask — used by the FlashAttn/H=1 baseline path."""
    i = jnp.arange(n)[:, None]
    j = jnp.arange(n)[None, :]
    return j <= i
