"""L2 — the Llama-architecture model in JAX, structured as the per-host
stage functions that aot.py lowers to HLO artifacts.

APB's communication happens *inside* each transformer layer (Algorithm 2):
compression + AllGather sit between the QKV projection and the attention of
the same layer. Each layer is therefore split into two artifacts:

  layer_pre   hidden -> (Q, K, V roped, compressed K_c/V_c + indices)
  layer_post  (hidden, Q, K, V, passing block) -> next hidden

with the AllGather owned by the rust coordinator between them. The decode
path (Algorithm 3) splits the same way around the Gather+LSE merge:

  decode_pre  hidden -> (q, k, v) for the new-token chunk
  decode_attn per-host partial attention + LSE   (kernel, lowered directly)
  decode_post merged attention -> next hidden

This module also contains `run_apb_pipeline`, a pure-python simulation of
the whole H-host cluster used to (a) unit-test the stage functions and
(b) emit golden files the rust integration tests replay bit-for-bit.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .configs import Config
from .kernels import (
    apb_attention,
    build_features,
    decode_attention,
    retaining_scores,
    top_lp_select,
)
from .kernels import ref as kref

# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

GLOBAL_PARAMS = ("embed", "final_norm", "lm_head")
LAYER_PARAMS = (
    "attn_norm", "wq", "wk", "wv", "wo",
    "ffn_norm", "w_gate", "w_up", "w_down",
    "rh_w1", "rh_b1", "rh_w2", "rh_b2",
)


def param_shapes(cfg: Config) -> dict[str, tuple[int, ...]]:
    """Deterministic name -> shape map; the manifest and weights.bin follow
    this exact order (globals first, then per-layer blocks)."""
    m = cfg.model
    hd, kh, h = m.head_dim, m.n_kv_heads, m.n_heads
    shapes: dict[str, tuple[int, ...]] = {
        "embed": (m.vocab_size, m.d_model),
        "final_norm": (m.d_model,),
        "lm_head": (m.d_model, m.vocab_size),
    }
    layer = {
        "attn_norm": (m.d_model,),
        "wq": (m.d_model, h * hd),
        "wk": (m.d_model, kh * hd),
        "wv": (m.d_model, kh * hd),
        "wo": (h * hd, m.d_model),
        "ffn_norm": (m.d_model,),
        "w_gate": (m.d_model, m.d_ff),
        "w_up": (m.d_model, m.d_ff),
        "w_down": (m.d_ff, m.d_model),
        "rh_w1": (3 * hd + 2, m.retaining_hidden),
        "rh_b1": (m.retaining_hidden,),
        "rh_w2": (m.retaining_hidden, 1),
        "rh_b2": (1,),
    }
    for i in range(m.n_layers):
        for name, shp in layer.items():
            shapes[f"layers.{i}.{name}"] = shp
    return shapes


def init_params(cfg: Config, seed: int | None = None) -> dict[str, jnp.ndarray]:
    """Scaled-gaussian init, deterministic in cfg.seed — with one
    structural property of *trained* LLMs imposed: query/key projections
    are aligned (W_q of each head = W_k of its kv-head + noise), so
    q_i.k_j is elevated when token i matches token j. Pretraining produces
    exactly this alignment (it is what makes induction/retrieval heads
    work); a fully random init has E[q.k] = 0 and cannot retrieve, which
    would void every retrieval-mechanism experiment (DESIGN.md §2)."""
    seed = cfg.seed if seed is None else seed
    rng = np.random.default_rng(seed)
    params = {}
    shapes = param_shapes(cfg)
    m = cfg.model
    for name, shp in shapes.items():
        if name.endswith(("_norm", "norm")):
            params[name] = jnp.ones(shp, jnp.float32)
        elif name.endswith(("rh_b1", "rh_b2")):
            params[name] = jnp.zeros(shp, jnp.float32)
        else:
            fan_in = shp[0] if len(shp) > 1 else shp[0]
            std = 1.0 / np.sqrt(fan_in)
            params[name] = jnp.asarray(
                rng.normal(0.0, std, size=shp), jnp.float32)
    # Align W_q with W_k per GQA group: wq[:, head i] = wk[:, i//g] + noise.
    hd, g = m.head_dim, m.gqa_groups
    for li in range(m.n_layers):
        wk = np.asarray(params[f"layers.{li}.wk"])          # [d, kh*hd]
        wq = np.asarray(params[f"layers.{li}.wq"]).copy()   # [d, h*hd]
        for h in range(m.n_heads):
            kv = h // g
            wq[:, h * hd:(h + 1) * hd] = (
                wk[:, kv * hd:(kv + 1) * hd] + 0.5 * wq[:, h * hd:(h + 1) * hd])
        params[f"layers.{li}.wq"] = jnp.asarray(wq, jnp.float32)
    return params


def layer_params(params: dict, i: int) -> dict[str, jnp.ndarray]:
    return {k: params[f"layers.{i}.{k}"] for k in LAYER_PARAMS}


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------

def rmsnorm(x, w, eps: float):
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def rope(x, positions, theta: float):
    """Rotary embedding. x: [n, heads, hd], positions: [n] i32."""
    n, h, hd = x.shape
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions.astype(jnp.float32)[:, None] * freqs[None, :]  # [n,half]
    cos = jnp.cos(angles)[:, None, :]
    sin = jnp.sin(angles)[:, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)


def swiglu(x, w_gate, w_up, w_down):
    g = jnp.dot(x, w_gate)
    u = jnp.dot(x, w_up)
    return jnp.dot(jax.nn.silu(g) * u, w_down)


# ---------------------------------------------------------------------------
# Prefill stage functions (Algorithm 2)
# ---------------------------------------------------------------------------

def embed(tokens, w_embed):
    """tokens [n] i32 -> hidden [n, d]."""
    return jnp.take(w_embed, tokens, axis=0)


def layer_pre(hidden, lp: dict, pos_offset, cfg: Config,
              interpret: bool = True):
    """QKV projection + RoPE + retaining-head scoring of the local block.

    hidden: [n_tot, d] with rows [anchor (l_aq) | local (l_b)].
    pos_offset: i32 scalar — global position of the first local token
                (l_q + (h-1)*l_b).
    Top-l_p selection itself is owned by the coordinator (rust) so the same
    artifact serves the retaining-head and random-selector ablations.
    Returns q [n,h,hd], k [n,kh,hd], v [n,kh,hd], scores [l_b,kh].
    """
    m, a = cfg.model, cfg.apb
    hd = m.head_dim
    x = rmsnorm(hidden, lp["attn_norm"], m.rms_eps)
    n = hidden.shape[0]
    q_nr = jnp.dot(x, lp["wq"]).reshape(n, m.n_heads, hd)
    k_nr = jnp.dot(x, lp["wk"]).reshape(n, m.n_kv_heads, hd)
    v = jnp.dot(x, lp["wv"]).reshape(n, m.n_kv_heads, hd)

    # Anchor rows sit at their true global positions 0..l_aq-1; local rows
    # at pos_offset..pos_offset+l_b-1. RoPE is applied BEFORE compression so
    # passed K_c blocks are directly attendable on other hosts.
    anchor_pos = jnp.arange(a.l_aq, dtype=jnp.int32)
    local_pos = pos_offset + jnp.arange(a.block_len, dtype=jnp.int32)
    positions = jnp.concatenate([anchor_pos, local_pos])
    q = rope(q_nr, positions, m.rope_theta)
    k = rope(k_nr, positions, m.rope_theta)

    # Compressor scores over the local block only (host-local view, §3.4),
    # conditioned on the embedded-query rows at the anchor front. Features
    # use PRE-RoPE projections so the query-similarity signal is position
    # independent (the query sits at different relative offsets during
    # training vs inference).
    feat = build_features(q_nr[a.l_aq:], k_nr[a.l_aq:], v[a.l_aq:],
                          q_query=q_nr[:a.query_len])
    scores = retaining_scores(feat, lp["rh_w1"], lp["rh_b1"], lp["rh_w2"],
                              lp["rh_b2"], interpret=interpret)
    return q, k, v, scores


def layer_post(hidden, q, k, v, k_pass, v_pass, pass_len, n_anchor,
               lp: dict, cfg: Config, interpret: bool = True):
    """APB attention over [anchor | passing | local] + O-proj + FFN.

    k_pass/v_pass: [pass_max, kh, hd], valid prefix pass_len. The passing
    block is discarded after attention (paper §3.6) — it never enters the
    FFN or the cache.
    """
    m, a = cfg.model, cfg.apb
    n = hidden.shape[0]
    k_attn = jnp.concatenate([k[:a.l_aq], k_pass, k[a.l_aq:]], axis=0)
    v_attn = jnp.concatenate([v[:a.l_aq], v_pass, v[a.l_aq:]], axis=0)
    att, _ = apb_attention(q, k_attn, v_attn, n_anchor, pass_len,
                           l_aq=a.l_aq, pass_max=a.pass_max,
                           bq=m.kernel_block_q, bk=m.kernel_block_k,
                           interpret=interpret)
    h = hidden + jnp.dot(att.reshape(n, -1), lp["wo"])
    x = rmsnorm(h, lp["ffn_norm"], m.rms_eps)
    return h + swiglu(x, lp["w_gate"], lp["w_up"], lp["w_down"])


# ---------------------------------------------------------------------------
# Decode stage functions (Algorithm 3)
# ---------------------------------------------------------------------------

def decode_pre(hidden, lp: dict, pos0, cfg: Config):
    """New-token chunk projection. hidden [n, d]; pos0 scalar i32."""
    m = cfg.model
    hd = m.head_dim
    n = hidden.shape[0]
    x = rmsnorm(hidden, lp["attn_norm"], m.rms_eps)
    q = jnp.dot(x, lp["wq"]).reshape(n, m.n_heads, hd)
    k = jnp.dot(x, lp["wk"]).reshape(n, m.n_kv_heads, hd)
    v = jnp.dot(x, lp["wv"]).reshape(n, m.n_kv_heads, hd)
    positions = pos0 + jnp.arange(n, dtype=jnp.int32)
    return rope(q, positions, m.rope_theta), rope(k, positions, m.rope_theta), v


def decode_post(hidden, att, lp: dict, cfg: Config):
    """Merged attention -> O-proj + residual + FFN. att: [n, h, hd]."""
    m = cfg.model
    n = hidden.shape[0]
    h = hidden + jnp.dot(att.reshape(n, -1), lp["wo"])
    x = rmsnorm(h, lp["ffn_norm"], m.rms_eps)
    return h + swiglu(x, lp["w_gate"], lp["w_up"], lp["w_down"])


def lm_head(hidden, w_norm, w_lm, cfg: Config):
    """Final norm + LM head. hidden [n, d] -> logits [n, V]."""
    return jnp.dot(rmsnorm(hidden, w_norm, cfg.model.rms_eps), w_lm)


# ---------------------------------------------------------------------------
# Deterministic pseudo-random compressor (the "Rd." ablation, Table 3).
# Must match rust/src/util/rng.rs::splitmix64 exactly.
# ---------------------------------------------------------------------------

def splitmix64(x: int) -> int:
    x = (x + 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF
    z = x
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & 0xFFFFFFFFFFFFFFFF
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & 0xFFFFFFFFFFFFFFFF
    return z ^ (z >> 31)


def random_scores(seed: int, layer: int, host: int, n: int, kh: int):
    """Pseudo-scores for the random-selector ablation; identical sequence is
    produced by the rust side (proptest'd)."""
    out = np.empty((n, kh), np.float32)
    for j in range(kh):
        for i in range(n):
            key = (seed << 40) ^ (layer << 28) ^ (host << 16) ^ (j << 12) ^ i
            out[i, j] = splitmix64(key & 0xFFFFFFFFFFFFFFFF) / 2.0**64
    return jnp.asarray(out)


# ---------------------------------------------------------------------------
# Whole-cluster golden pipeline (python simulation of the rust coordinator)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ApbOptions:
    """Ablation toggles (paper Table 3).

    `method` mirrors the rust `AttnMethod` spellings for the anchored
    prefill family this python pipeline simulates: "apb" (anchor +
    compressed passing blocks) or "star" (anchor only, no passing — the
    former `use_passing=False`). The exact baselines (ring/dense) live in
    the rust cluster and the numpy mirror tests, not here.
    """
    method: str = "apb"           # "P": apb | star
    use_anchor: bool = True       # "A"
    compressor: str = "retaining"  # "C": retaining | random
    embed_query: bool = True      # "Q"
    rd_seed: int = 1234

    def __post_init__(self):
        if self.method not in ("apb", "star"):
            raise ValueError(f"unknown method {self.method!r} "
                             "(expected 'apb' or 'star')")


def host_tokens(cfg: Config, doc: np.ndarray, query: np.ndarray, host: int,
                opts: ApbOptions) -> np.ndarray:
    """Token layout for one host: [anchor (l_aq) | local block].

    Host 0 (paper's host 1) has no anchor; the slot is zero-filled and
    masked out via n_anchor=0. With embed_query off, the query slot is
    zero-filled (anchor = document head only, Table 3 "Q" ablation)."""
    a = cfg.apb
    block = doc[host * a.block_len:(host + 1) * a.block_len]
    anchor = np.zeros(a.l_aq, np.int32)
    if host > 0 and opts.use_anchor:
        if opts.embed_query:
            anchor[:a.query_len] = query
        anchor[a.query_len:] = doc[:a.anchor_len]
    return np.concatenate([anchor, block.astype(np.int32)])


def n_anchor_for(cfg: Config, host: int, opts: ApbOptions) -> int:
    return cfg.apb.l_aq if (host > 0 and opts.use_anchor) else 0


def run_apb_prefill(params, cfg: Config, doc, query, opts=ApbOptions(),
                    interpret: bool = True):
    """Simulate the H-host APB prefill. Returns per-host per-layer local KV
    caches and final hidden states.

    caches[h][l] = (k_local [l_b,kh,hd], v_local) — what Algorithm 2 appends.
    """
    a = cfg.apb
    H = a.n_hosts
    hiddens = []
    for h in range(H):
        toks = host_tokens(cfg, doc, query, h, opts)
        hiddens.append(embed(jnp.asarray(toks), params["embed"]))

    caches: list[list[tuple]] = [[] for _ in range(H)]
    for li in range(cfg.model.n_layers):
        lp = layer_params(params, li)
        pre = []
        for h in range(H):
            pos_offset = a.query_len + h * a.block_len
            q, k, v, scores = layer_pre(hiddens[h], lp, pos_offset, cfg,
                                        interpret=interpret)
            if opts.compressor == "random":
                scores = random_scores(opts.rd_seed, li, h, a.block_len,
                                       cfg.model.n_kv_heads)
            k_c, v_c, idx = top_lp_select(scores, k[a.l_aq:], v[a.l_aq:],
                                          a.passing_len)
            pre.append((q, k, v, k_c, v_c))
        # AllGather of compressed blocks; host h keeps blocks from hosts < h.
        for h in range(H):
            q, k, v, _, _ = pre[h]
            n_pass = h * a.passing_len if opts.method == "apb" else 0
            k_pass = jnp.zeros((a.pass_max, cfg.model.n_kv_heads,
                                cfg.model.head_dim), jnp.float32)
            v_pass = jnp.zeros_like(k_pass)
            if n_pass > 0:
                kp = jnp.concatenate([pre[g][3] for g in range(h)], axis=0)
                vp = jnp.concatenate([pre[g][4] for g in range(h)], axis=0)
                k_pass = k_pass.at[:n_pass].set(kp)
                v_pass = v_pass.at[:n_pass].set(vp)
            n_anc = n_anchor_for(cfg, h, opts)
            hiddens[h] = layer_post(hiddens[h], q, k, v, k_pass, v_pass,
                                    n_pass, n_anc, lp, cfg,
                                    interpret=interpret)
            caches[h].append((k[a.l_aq:], v[a.l_aq:]))
    return caches, hiddens


def run_decode(params, cfg: Config, caches, query, n_new: int,
               interpret: bool = True):
    """Simulate distributed decode (Algorithm 3): process the query chunk
    with exact distributed attention, then greedy-decode n_new tokens.

    Returns (generated token ids [n_new], query-chunk logits [l_q, V])."""
    a, m = cfg.apb, cfg.model
    H = a.n_hosts
    cmax = a.cache_max

    # Padded per-host caches; host H-1 grows with the chunk + new tokens.
    k_cache = [jnp.zeros((cmax, m.n_kv_heads, m.head_dim), jnp.float32)
               for _ in range(H)]
    v_cache = [jnp.zeros_like(k_cache[0]) for _ in range(H)]
    cache_len = [a.block_len] * H
    layer_k, layer_v = [], []
    for li in range(m.n_layers):
        lk, lv = [], []
        for h in range(H):
            kc, vc = caches[h][li]
            lk.append(k_cache[h].at[:a.block_len].set(kc))
            lv.append(v_cache[h].at[:a.block_len].set(vc))
        layer_k.append(lk)
        layer_v.append(lv)
    cache_lens = [[a.block_len] * H for _ in range(m.n_layers)]

    def step(tokens: np.ndarray, pos0: int):
        n = len(tokens)
        hidden = embed(jnp.asarray(tokens, jnp.int32), params["embed"])
        for li in range(m.n_layers):
            lp = layer_params(params, li)
            q, k, v = decode_pre(hidden, lp, pos0, cfg)
            outs, lses = [], []
            for h in range(H):
                if h == H - 1:
                    cl = cache_lens[li][h]
                    layer_k[li][h] = jax.lax.dynamic_update_slice(
                        layer_k[li][h], k, (cl, 0, 0))
                    layer_v[li][h] = jax.lax.dynamic_update_slice(
                        layer_v[li][h], v, (cl, 0, 0))
                    cache_lens[li][h] = cl + n
                    o, s = decode_attention(q, layer_k[li][h],
                                            layer_v[li][h],
                                            cache_lens[li][h], 1,
                                            interpret=interpret)
                else:
                    o, s = decode_attention(q, layer_k[li][h],
                                            layer_v[li][h],
                                            cache_lens[li][h], 0,
                                            interpret=interpret)
                outs.append(o)
                lses.append(s)
            att, _ = kref.merge_partials_ref(outs, lses)
            hidden = decode_post(hidden, att, lp, cfg)
        return lm_head(hidden, params["final_norm"], params["lm_head"], cfg)

    # Query chunk at positions l_q + l_d ...
    pos0 = a.query_len + a.doc_len
    logits = step(np.asarray(query, np.int32), pos0)
    gen = []
    tok = int(jnp.argmax(logits[-1]))
    for i in range(n_new):
        gen.append(tok)
        lg = step(np.asarray([tok], np.int32), pos0 + a.query_len + i)
        tok = int(jnp.argmax(lg[-1]))
    return np.asarray(gen, np.int32), np.asarray(logits)


def run_exact_reference(params, cfg: Config, doc, query, n_new: int):
    """Single-host exact-attention reference (the FULLATTN baseline):
    causal prefill over [query-at-front? no —] document, then the same
    decode path with H=1 semantics. Used for approximation-error metrics."""
    a, m = cfg.apb, cfg.model
    # Document tokens at global positions l_q .. l_q + l_d - 1 (identical
    # position layout to APB so errors measure the approximation only).
    hidden = embed(jnp.asarray(doc, jnp.int32), params["embed"])
    caches = []
    pos = a.query_len + jnp.arange(a.doc_len, dtype=jnp.int32)
    for li in range(m.n_layers):
        lp = layer_params(params, li)
        x = rmsnorm(hidden, lp["attn_norm"], m.rms_eps)
        n = hidden.shape[0]
        q = jnp.dot(x, lp["wq"]).reshape(n, m.n_heads, m.head_dim)
        k = jnp.dot(x, lp["wk"]).reshape(n, m.n_kv_heads, m.head_dim)
        v = jnp.dot(x, lp["wv"]).reshape(n, m.n_kv_heads, m.head_dim)
        q = rope(q, pos, m.rope_theta)
        k = rope(k, pos, m.rope_theta)
        att, _ = kref.attention_ref(q, k, v, kref.causal_mask(n))
        h = hidden + jnp.dot(att.reshape(n, -1), lp["wo"])
        xf = rmsnorm(h, lp["ffn_norm"], m.rms_eps)
        hidden = h + swiglu(xf, lp["w_gate"], lp["w_up"], lp["w_down"])
        caches.append((k, v))
    return caches, hidden
