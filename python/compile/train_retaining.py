"""Build-time training of the Locret-style retaining heads (paper §B.1).

The paper trains small per-layer MLPs ("retaining heads" R) on long-context
SFT data (LongAlign) to regress an importance score per KV unit; the score
Locret regresses is the attention mass the unit later receives — the same
quantity SNAPKV reads off directly from the observation window. We have no
LongAlign and no pretrained backbone, so we reproduce the *mechanism*:

  1. sample synthetic sequences with planted "needle" n-grams that the last
     `window` tokens (the observation window, standing in for the query)
     repeat — giving the backbone a reason to attend back to them;
  2. run the frozen random-weights backbone, collect per-layer roped Q/K/V;
  3. label each position with its (log-scaled) attention mass received from
     the observation-window queries — the SnapKV oracle;
  4. regress the retaining-head MLP on those labels (MSE + the smoothing
     term of Locret), AdamW-style updates.

What this preserves from the paper: the retaining head becomes a *trained,
query-aware* ranker of KV units that beats the random selector at keeping
exactly the units the backbone's own attention needs — which is the
property Table 3 ablates (R vs "Rd.").
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .configs import Config
from .kernels import build_features
from .kernels import ref as kref
from . import model as M


def make_training_batch(cfg: Config, rng: np.random.Generator, seq_len: int,
                        window: int, batch: int):
    """Synthetic needle sequences: random tokens, with `n_needles` short
    n-grams planted in the body and repeated inside the observation window
    so attention from the window has real targets to retrieve."""
    V = cfg.model.vocab_size
    toks = rng.integers(1, V, size=(batch, seq_len), dtype=np.int64)
    n_needles = 4
    span = 4
    for b in range(batch):
        for _ in range(n_needles):
            pos = int(rng.integers(0, seq_len - window - span))
            gram = rng.integers(1, V, size=span)
            toks[b, pos:pos + span] = gram
            wpos = int(rng.integers(seq_len - window, seq_len - span))
            toks[b, wpos:wpos + span] = gram
    return toks.astype(np.int32)


def backbone_qkv(params, cfg: Config, tokens):
    """Frozen-backbone forward collecting per-layer roped Q/K/V.
    tokens: [n] -> list of (q [n,h,hd], k [n,kh,hd], v [n,kh,hd])."""
    m = cfg.model
    hidden = M.embed(jnp.asarray(tokens), params["embed"])
    pos = jnp.arange(tokens.shape[0], dtype=jnp.int32)
    out = []
    for li in range(m.n_layers):
        lp = M.layer_params(params, li)
        x = M.rmsnorm(hidden, lp["attn_norm"], m.rms_eps)
        n = hidden.shape[0]
        q = jnp.dot(x, lp["wq"]).reshape(n, m.n_heads, m.head_dim)
        k = jnp.dot(x, lp["wk"]).reshape(n, m.n_kv_heads, m.head_dim)
        v = jnp.dot(x, lp["wv"]).reshape(n, m.n_kv_heads, m.head_dim)
        q_roped = M.rope(q, pos, m.rope_theta)
        k_roped = M.rope(k, pos, m.rope_theta)
        # (roped for attention labels, pre-rope for compressor features)
        out.append((q_roped, k_roped, v, q, k))
        q, k = q_roped, k_roped
        att, _ = kref.attention_ref(q, k, v, kref.causal_mask(n))
        h = hidden + jnp.dot(att.reshape(n, -1), lp["wo"])
        xf = M.rmsnorm(h, lp["ffn_norm"], m.rms_eps)
        hidden = h + M.swiglu(xf, lp["w_gate"], lp["w_up"], lp["w_down"])
    return out


def snapkv_labels(q, k, window: int):
    """Attention mass each key receives from the last `window` queries,
    max-pooled over GQA group and log-scaled. q:[n,h,hd] k:[n,kh,hd] ->
    labels [n-window, kh]."""
    n, h, hd = q.shape
    kh = k.shape[1]
    g = h // kh
    qw = q[n - window:].astype(jnp.float32)                 # [w,h,hd]
    kf = k.astype(jnp.float32)
    kv_idx = jnp.arange(h) // g
    ke = kf[:, kv_idx, :]                                   # [n,h,hd]
    s = jnp.einsum("whd,nhd->hwn", qw, ke) / np.sqrt(hd)
    p = jax.nn.softmax(s, axis=-1)                          # [h,w,n]
    mass = p.sum(axis=1)                                    # [h,n]
    mass = mass.reshape(kh, g, n).max(axis=1)               # [kh,n]
    lab = jnp.log1p(mass * window)                          # compress range
    return lab.T[: n - window]                              # [n-w, kh]


def rh_forward(rh, feat):
    h = jnp.dot(feat, rh["w1"]) + rh["b1"]
    h = jax.nn.gelu(h, approximate=True)
    return (jnp.dot(h, rh["w2"]) + rh["b2"])[..., 0]


def train_retaining_heads(params, cfg: Config, *, steps: int = 150,
                          seq_len: int | None = None, window: int = 16,
                          batch: int = 2, lr: float = 3e-3,
                          alpha: float = 0.0025, seed: int = 7,
                          log_every: int = 50, verbose: bool = True):
    """Train all layers' retaining heads; returns updated params plus a
    per-layer recall@l_p diagnostic (trained-vs-random) dict."""
    m = cfg.model
    seq_len = seq_len or min(cfg.apb.n_tot, 320)
    rng = np.random.default_rng(seed)

    # Precompute dataset: features + labels for each (sample, layer).
    feats = [[] for _ in range(m.n_layers)]
    labels = [[] for _ in range(m.n_layers)]
    n_samples = max(4, batch * 2)
    toks = make_training_batch(cfg, rng, seq_len, window, n_samples)
    for b in range(n_samples):
        qkv = backbone_qkv(params, cfg, toks[b])
        for li, (q, k, v, q_nr, k_nr) in enumerate(qkv):
            lab = snapkv_labels(q, k, window)
            # Window rows stand in for the embedded query (same role the
            # anchor's query rows play at inference); pre-RoPE features.
            feat = build_features(q_nr, k_nr, v,
                                  q_query=q_nr[seq_len - window:])[: seq_len - window]
            feats[li].append(np.asarray(feat))
            labels[li].append(np.asarray(lab))

    rh_params = []
    for li in range(m.n_layers):
        rh_params.append({
            "w1": params[f"layers.{li}.rh_w1"],
            "b1": params[f"layers.{li}.rh_b1"],
            "w2": params[f"layers.{li}.rh_w2"],
            "b2": params[f"layers.{li}.rh_b2"],
        })

    def loss_fn(rh, feat, lab):
        pred = rh_forward(rh, feat)                         # [n,kh]
        mse = jnp.mean((pred - lab) ** 2)
        # Locret's smoothing term: neighbouring units get similar scores.
        smooth = jnp.mean((pred[1:] - pred[:-1]) ** 2)
        return mse + alpha * smooth

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))

    # Plain Adam, per layer.
    beta1, beta2, eps = 0.9, 0.95, 1e-8
    history = {}
    for li in range(m.n_layers):
        rh = {k: np.asarray(v, np.float32) for k, v in rh_params[li].items()}
        mom = {k: np.zeros_like(v) for k, v in rh.items()}
        var = {k: np.zeros_like(v) for k, v in rh.items()}
        X = np.concatenate(feats[li], axis=0)
        Y = np.concatenate(labels[li], axis=0)
        n = X.shape[0]
        losses = []
        for t in range(1, steps + 1):
            idx = rng.integers(0, n, size=min(n, 1024))
            lv, g = grad_fn({k: jnp.asarray(v) for k, v in rh.items()},
                            jnp.asarray(X[idx]), jnp.asarray(Y[idx]))
            losses.append(float(lv))
            for k2 in rh:
                gk = np.asarray(g[k2])
                mom[k2] = beta1 * mom[k2] + (1 - beta1) * gk
                var[k2] = beta2 * var[k2] + (1 - beta2) * gk * gk
                mh = mom[k2] / (1 - beta1 ** t)
                vh = var[k2] / (1 - beta2 ** t)
                rh[k2] = rh[k2] - lr * mh / (np.sqrt(vh) + eps)
        for k2, name in (("w1", "rh_w1"), ("b1", "rh_b1"),
                         ("w2", "rh_w2"), ("b2", "rh_b2")):
            params[f"layers.{li}.{name}"] = jnp.asarray(rh[k2])
        # Diagnostic: recall@l_p of the true top-mass units vs random.
        lp = cfg.apb.passing_len
        pred = np.asarray(rh_forward({k: jnp.asarray(v)
                                      for k, v in rh.items()},
                                     jnp.asarray(X)))
        recall = _recall_at(pred, Y, lp)
        rand_recall = lp / max(1, Y.shape[0])
        history[li] = {"loss0": losses[0], "lossN": losses[-1],
                       "recall": recall, "rand_recall": rand_recall}
        if verbose:
            print(f"[retaining] layer {li}: loss {losses[0]:.4f} -> "
                  f"{losses[-1]:.4f}, recall@{lp} {recall:.3f} "
                  f"(random {rand_recall:.3f})")
    return params, history


def _recall_at(pred: np.ndarray, lab: np.ndarray, lp: int) -> float:
    """Fraction of the true top-lp units (per kv-head) that the predicted
    top-lp keeps."""
    n, kh = pred.shape
    lp = min(lp, n)
    hits = 0
    for j in range(kh):
        top_true = set(np.argsort(-lab[:, j])[:lp].tolist())
        top_pred = set(np.argsort(-pred[:, j])[:lp].tolist())
        hits += len(top_true & top_pred)
    return hits / (kh * lp)
