"""AOT pipeline: lower every per-host stage function to HLO *text* and dump
weights + manifest + golden files for the rust coordinator.

HLO text (NOT `.serialize()`) is the interchange format: the image's
xla_extension 0.5.1 rejects jax>=0.5 protos with 64-bit instruction ids;
the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md). Lowered with return_tuple=True; the rust side
unwraps with to_tuple().

Usage:  python -m compile.aot --config tiny --out ../artifacts
        python -m compile.aot --all --out ../artifacts
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .configs import CONFIGS, Config, get_config
from . import model as M
from .train_retaining import train_retaining_heads

jax.config.update("jax_platform_name", "cpu")


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (reference recipe)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _scalar():
    return spec((), jnp.int32)


def stage_functions(cfg: Config):
    """Every artifact: name -> (fn, [(arg_name, ShapeDtypeStruct)]).

    Weight arguments are named exactly like the manifest weight entries
    (with a `layers.{i}.` prefix stripped to the per-layer name) so the
    rust runtime can bind them mechanically.
    """
    m, a = cfg.model, cfg.apb
    d, hd, h, kh = m.d_model, m.head_dim, m.n_heads, m.n_kv_heads
    shapes = M.param_shapes(cfg)

    def w(name):
        key = name if name in shapes else f"layers.0.{name}"
        return spec(shapes[key])

    stages = {}

    def embed_fn(tokens, w_embed):
        return (M.embed(tokens, w_embed),)

    for name, n in (("embed_prefill", a.n_tot), ("embed_query", a.query_len),
                    ("embed_step", 1)):
        stages[name] = (embed_fn, [("tokens", spec((n,), jnp.int32)),
                                   ("embed", w("embed"))])

    def layer_pre_fn(hidden, pos_offset, attn_norm, wq, wk, wv,
                     rh_w1, rh_b1, rh_w2, rh_b2):
        lp = {"attn_norm": attn_norm, "wq": wq, "wk": wk, "wv": wv,
              "rh_w1": rh_w1, "rh_b1": rh_b1, "rh_w2": rh_w2, "rh_b2": rh_b2}
        q, k, v, scores = M.layer_pre(hidden, lp, pos_offset, cfg)
        return q, k, v, scores

    stages["layer_pre"] = (layer_pre_fn, [
        ("hidden", spec((a.n_tot, d))),
        ("pos_offset", _scalar()),
        ("attn_norm", w("attn_norm")), ("wq", w("wq")), ("wk", w("wk")),
        ("wv", w("wv")), ("rh_w1", w("rh_w1")), ("rh_b1", w("rh_b1")),
        ("rh_w2", w("rh_w2")), ("rh_b2", w("rh_b2")),
    ])

    def layer_post_fn(hidden, q, k, v, k_pass, v_pass, pass_len, n_anchor,
                      wo, ffn_norm, w_gate, w_up, w_down):
        lp = {"wo": wo, "ffn_norm": ffn_norm, "w_gate": w_gate,
              "w_up": w_up, "w_down": w_down}
        return (M.layer_post(hidden, q, k, v, k_pass, v_pass, pass_len,
                             n_anchor, lp, cfg),)

    stages["layer_post"] = (layer_post_fn, [
        ("hidden", spec((a.n_tot, d))),
        ("q", spec((a.n_tot, h, hd))),
        ("k", spec((a.n_tot, kh, hd))),
        ("v", spec((a.n_tot, kh, hd))),
        ("k_pass", spec((a.pass_max, kh, hd))),
        ("v_pass", spec((a.pass_max, kh, hd))),
        ("pass_len", _scalar()), ("n_anchor", _scalar()),
        ("wo", w("wo")), ("ffn_norm", w("ffn_norm")),
        ("w_gate", w("w_gate")), ("w_up", w("w_up")),
        ("w_down", w("w_down")),
    ])

    def decode_pre_fn(hidden, pos0, attn_norm, wq, wk, wv):
        lp = {"attn_norm": attn_norm, "wq": wq, "wk": wk, "wv": wv}
        return M.decode_pre(hidden, lp, pos0, cfg)

    def decode_attn_fn(q, k_cache, v_cache, cache_len, self_causal):
        from .kernels import decode_attention
        return decode_attention(q, k_cache, v_cache, cache_len, self_causal,
                                bq=m.kernel_block_q, bk=m.kernel_block_k)

    def decode_post_fn(hidden, att, wo, ffn_norm, w_gate, w_up, w_down):
        lp = {"wo": wo, "ffn_norm": ffn_norm, "w_gate": w_gate,
              "w_up": w_up, "w_down": w_down}
        return (M.decode_post(hidden, att, lp, cfg),)

    def lm_head_fn(hidden, final_norm, w_lm):
        return (M.lm_head(hidden, final_norm, w_lm, cfg),)

    for tag, n in (("query", a.query_len), ("step", 1)):
        stages[f"decode_pre_{tag}"] = (decode_pre_fn, [
            ("hidden", spec((n, d))), ("pos0", _scalar()),
            ("attn_norm", w("attn_norm")), ("wq", w("wq")),
            ("wk", w("wk")), ("wv", w("wv")),
        ])
        stages[f"decode_attn_{tag}"] = (decode_attn_fn, [
            ("q", spec((n, h, hd))),
            ("k_cache", spec((a.cache_max, kh, hd))),
            ("v_cache", spec((a.cache_max, kh, hd))),
            ("cache_len", _scalar()), ("self_causal", _scalar()),
        ])
        stages[f"decode_post_{tag}"] = (decode_post_fn, [
            ("hidden", spec((n, d))), ("att", spec((n, h, hd))),
            ("wo", w("wo")), ("ffn_norm", w("ffn_norm")),
            ("w_gate", w("w_gate")), ("w_up", w("w_up")),
            ("w_down", w("w_down")),
        ])
        stages[f"lm_head_{tag}"] = (lm_head_fn, [
            ("hidden", spec((n, d))), ("final_norm", w("final_norm")),
            ("lm_head", w("lm_head")),
        ])
    return stages


def write_blob(path: str, arrays: dict[str, np.ndarray]):
    """Concatenate f32/i32 arrays little-endian; return manifest entries."""
    entries = []
    offset = 0
    with open(path, "wb") as f:
        for name, arr in arrays.items():
            arr = np.ascontiguousarray(arr)
            dtype = "i32" if arr.dtype == np.int32 else "f32"
            raw = arr.astype("<i4" if dtype == "i32" else "<f4").tobytes()
            f.write(raw)
            entries.append({"name": name, "dtype": dtype,
                            "shape": list(arr.shape), "offset": offset,
                            "size": len(raw)})
            offset += len(raw)
    return entries


def build_golden(params, cfg: Config, n_new: int = 4, seed: int = 42):
    """Run the python cluster simulation end-to-end; the rust integration
    test replays the same artifacts and must reproduce these outputs."""
    rng = np.random.default_rng(seed)
    doc = rng.integers(1, cfg.model.vocab_size,
                       cfg.apb.doc_len).astype(np.int32)
    query = rng.integers(1, cfg.model.vocab_size,
                         cfg.apb.query_len).astype(np.int32)
    caches, hiddens = M.run_apb_prefill(params, cfg, doc, query)
    gen, logits = M.run_decode(params, cfg, caches, query, n_new)
    arrays = {
        "doc_tokens": doc,
        "query_tokens": query,
        "generated": gen.astype(np.int32),
        "query_logits": np.asarray(logits, np.float32),
        "host0_hidden": np.asarray(hiddens[0], np.float32),
        "hostH_hidden": np.asarray(hiddens[-1], np.float32),
        "host0_cache_k_l0": np.asarray(caches[0][0][0], np.float32),
        "hostH_cache_v_lN": np.asarray(caches[-1][-1][1], np.float32),
    }
    return arrays


def build(cfg: Config, out_dir: str, train_steps: int, golden: bool,
          golden_new: int = 4, verbose: bool = True):
    os.makedirs(out_dir, exist_ok=True)
    params = M.init_params(cfg)
    history = {}
    if train_steps > 0:
        params, history = train_retaining_heads(
            params, cfg, steps=train_steps, verbose=verbose)

    # --- weights.bin ---------------------------------------------------
    weights = {name: np.asarray(params[name], np.float32)
               for name in M.param_shapes(cfg)}
    weight_entries = write_blob(os.path.join(out_dir, "weights.bin"), weights)

    # --- HLO artifacts --------------------------------------------------
    artifact_meta = {}
    for name, (fn, args) in stage_functions(cfg).items():
        lowered = jax.jit(fn).lower(*[s for _, s in args])
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        outs = lowered.out_info
        leaves = jax.tree_util.tree_leaves(outs)
        artifact_meta[name] = {
            "file": fname,
            "inputs": [{"name": n, "dtype": str(s.dtype),
                        "shape": list(s.shape)} for n, s in args],
            "outputs": [{"dtype": str(o.dtype), "shape": list(o.shape)}
                        for o in leaves],
        }
        if verbose:
            print(f"[aot] {name}: {len(text)} chars, "
                  f"{len(args)} inputs, {len(leaves)} outputs")

    # --- golden end-to-end run ------------------------------------------
    golden_entry = None
    if golden:
        arrays = build_golden(params, cfg, n_new=golden_new)
        golden_entries = write_blob(os.path.join(out_dir, "golden.bin"),
                                    arrays)
        golden_entry = {"file": "golden.bin", "n_new": golden_new,
                        "entries": golden_entries}
        if verbose:
            print(f"[aot] golden: generated={arrays['generated'].tolist()}")

    manifest = {
        "config": cfg.to_json(),
        "artifacts": artifact_meta,
        "weights": {"file": "weights.bin", "entries": weight_entries},
        "golden": golden_entry,
        "retaining_history": {str(k): v for k, v in history.items()},
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if verbose:
        print(f"[aot] wrote {out_dir}/manifest.json")


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--config", default="tiny", choices=list(CONFIGS))
    p.add_argument("--all", action="store_true")
    p.add_argument("--out", default="../artifacts")
    p.add_argument("--train-steps", type=int, default=150)
    p.add_argument("--no-golden", action="store_true")
    p.add_argument("--golden-new", type=int, default=4)
    args = p.parse_args()
    names = list(CONFIGS) if args.all else [args.config]
    for name in names:
        cfg = get_config(name)
        golden = (not args.no_golden) and name == "tiny"
        build(cfg, os.path.join(args.out, name), args.train_steps, golden,
              golden_new=args.golden_new)


if __name__ == "__main__":
    main()
