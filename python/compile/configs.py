"""Model / cluster / APB hyperparameter configs shared by the compile path
(python) and the coordinator (rust, via artifacts/manifest.json).

All sequence-layout quantities follow the paper's notation (§3.3):
  l_q  query length (embedded at the front of every anchor block)
  l_a  anchor length (first l_a document tokens)
  l_b  per-host local block length (= l_d / H)
  l_p  passing length (top-l_p KV units retained by the compressor)
  H    number of hosts (sequence-parallel size)

The HLO artifacts are compiled with static shapes; per-host variation
(host 1 has no anchor block, host h receives (h-1)*l_p passing units) is
expressed at runtime through two scalar operands:
  n_anchor  in {0, l_aq}  — masks the anchor segment in/out
  pass_len  in [0, Pmax]  — valid prefix of the padded passing segment
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Llama-architecture dims (RMSNorm + RoPE + GQA + SwiGLU)."""

    vocab_size: int = 512
    n_layers: int = 4
    d_model: int = 128
    n_heads: int = 4
    n_kv_heads: int = 2
    d_ff: int = 256
    rope_theta: float = 10000.0
    rms_eps: float = 1e-5
    # Retaining-head (Locret) compressor MLP: [q_mean, k, v] -> r -> 1
    retaining_hidden: int = 64
    # Pallas kernel tile sizes. 128x128 is the MXU-shaped TPU default; the
    # CPU-interpret artifacts use one big tile because interpret-mode loop
    # overhead dominates there (§Perf L1 iteration log). Block-size
    # invariance is pinned by test_apb_attention_block_size_invariance.
    kernel_block_q: int = 1024
    kernel_block_k: int = 1024

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def gqa_groups(self) -> int:
        assert self.n_heads % self.n_kv_heads == 0
        return self.n_heads // self.n_kv_heads


@dataclasses.dataclass(frozen=True)
class ApbConfig:
    """Sequence layout + cluster topology for one compiled artifact set."""

    n_hosts: int = 4
    block_len: int = 256          # l_b
    anchor_len: int = 32          # l_a
    query_len: int = 16           # l_q
    passing_len: int = 32         # l_p
    max_new_tokens: int = 64

    @property
    def l_aq(self) -> int:
        """Anchor block total length: query embedded before document head."""
        return self.query_len + self.anchor_len

    @property
    def n_tot(self) -> int:
        """Per-host prefill sequence length: [anchor | local block]."""
        return self.l_aq + self.block_len

    @property
    def pass_max(self) -> int:
        """Padded passing-segment capacity: (H-1) compressed blocks."""
        return (self.n_hosts - 1) * self.passing_len

    @property
    def doc_len(self) -> int:
        return self.n_hosts * self.block_len

    @property
    def cache_max(self) -> int:
        """Decode-time KV cache capacity. Host H additionally stores the
        re-processed query and generated tokens."""
        return self.block_len + self.query_len + self.max_new_tokens


@dataclasses.dataclass(frozen=True)
class Config:
    model: ModelConfig
    apb: ApbConfig
    seed: int = 0
    name: str = "tiny"

    def to_json(self) -> dict[str, Any]:
        return {
            "name": self.name,
            "seed": self.seed,
            "model": dataclasses.asdict(self.model),
            "apb": dataclasses.asdict(self.apb),
            "derived": {
                "head_dim": self.model.head_dim,
                "gqa_groups": self.model.gqa_groups,
                "l_aq": self.apb.l_aq,
                "n_tot": self.apb.n_tot,
                "pass_max": self.apb.pass_max,
                "doc_len": self.apb.doc_len,
                "cache_max": self.apb.cache_max,
            },
        }

    def dumps(self) -> str:
        return json.dumps(self.to_json(), indent=2)


# Smallest config: unit tests / CI. Everything fits in seconds on one core.
TINY = Config(
    name="tiny",
    model=ModelConfig(),
    apb=ApbConfig(n_hosts=4, block_len=256, anchor_len=32, query_len=16,
                  passing_len=32, max_new_tokens=64),
)

# End-to-end serving demo: a bigger model + longer context, still CPU-viable.
E2E = Config(
    name="e2e",
    model=ModelConfig(vocab_size=2048, n_layers=6, d_model=256, n_heads=8,
                      n_kv_heads=4, d_ff=688, retaining_hidden=128),
    apb=ApbConfig(n_hosts=4, block_len=512, anchor_len=128, query_len=32,
                  passing_len=64, max_new_tokens=32),
)

CONFIGS = {c.name: c for c in (TINY, E2E)}


def get_config(name: str) -> Config:
    return CONFIGS[name]
