"""Python mirror of rust/src/runtime/sim.rs plus the RingAttn/Dense
prefill and decode orchestration in rust/src/coordinator/host.rs, verifying
the exactness invariant (RingAttn == Dense) independently of the Rust
toolchain. f64 throughout: this checks the ALGORITHM — token layouts,
global positions, ring-origin bookkeeping, position-causal masks, the
online-softmax merge, and the distributed query-chunk decode — not f32
rounding (the Rust test `cluster_modes::ring_matches_dense_oracle_within_1e5`
covers that at 1e-5).

Runs standalone (`python3 test_ring_dense_mirror.py`, numpy only) or under
pytest alongside the jax-based suite."""
import math
import numpy as np

MASK64 = (1 << 64) - 1


def splitmix64(x):
    x = (x + 0x9E3779B97F4A7C15) & MASK64
    z = x
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
    return (z ^ (z >> 31)) & MASK64


class Rng:
    def __init__(self, seed):
        s = []
        x = seed & MASK64
        for _ in range(4):
            x = (x + 0x9E3779B97F4A7C15) & MASK64
            s.append(splitmix64(x))
        self.s = s

    def next_u64(self):
        s = self.s
        def rotl(v, k):
            return ((v << k) | (v >> (64 - k))) & MASK64
        result = (rotl((s[1] * 5) & MASK64, 7) * 9) & MASK64
        t = (s[1] << 17) & MASK64
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = rotl(s[3], 45)
        return result

    def f64(self):
        return (self.next_u64() >> 11) / float(1 << 53)

    def normal(self):
        u1 = max(self.f64(), 1e-300)
        u2 = self.f64()
        return math.sqrt(-2.0 * math.log(u1)) * math.cos(2.0 * math.pi * u2)


# --- sim_tiny config ---
VOCAB, L, D, H, KH, DFF = 128, 2, 32, 4, 2, 64
THETA, EPS = 1e4, 1e-5
HOSTS, LB, LA, LQ, LP, MAXNEW = 3, 32, 8, 4, 8, 8
HD = D // H
G = H // KH
DOC_LEN = HOSTS * LB


def normal_tensor(rng, shape):
    fan_in = shape[0]
    std = 1.0 / math.sqrt(fan_in)
    n = int(np.prod(shape))
    data = np.array([rng.normal() * std for _ in range(n)])
    return data.reshape(shape)


def build_weights(seed=1234):
    rng = Rng(seed ^ 0xA9B0C0DE)
    embed = normal_tensor(rng, (VOCAB, D))
    lm_head_w = normal_tensor(rng, (D, VOCAB))
    layers = []
    for _ in range(L):
        wq = normal_tensor(rng, (D, H * HD))
        wk = normal_tensor(rng, (D, KH * HD))
        wv = normal_tensor(rng, (D, KH * HD))
        wo = normal_tensor(rng, (H * HD, D))
        # GQA alignment: wq[:, head hh] = wk[:, hh//G] + 0.5 * wq
        wq2 = wq.copy()
        for r in range(D):
            for hh in range(H):
                kv = hh // G
                for c in range(HD):
                    wq2[r, hh * HD + c] = wk[r, kv * HD + c] + 0.5 * wq[r, hh * HD + c]
        w_gate = normal_tensor(rng, (D, DFF))
        w_up = normal_tensor(rng, (D, DFF))
        w_down = normal_tensor(rng, (DFF, D))
        layers.append(dict(wq=wq2, wk=wk, wv=wv, wo=wo, w_gate=w_gate,
                           w_up=w_up, w_down=w_down))
    return embed, lm_head_w, layers


def rmsnorm(x):
    var = np.mean(x * x, axis=-1, keepdims=True)
    return x / np.sqrt(var + EPS)


def rope(x, positions):
    # x: [n, heads, hd]; half-split rotation
    n, h, hd = x.shape
    half = hd // 2
    out = x.copy()
    freqs = np.array([THETA ** (-(t / half)) for t in range(half)])
    for i in range(n):
        ang = positions[i] * freqs
        sin, cos = np.sin(ang), np.cos(ang)
        x1 = x[i, :, :half]
        x2 = x[i, :, half:]
        out[i, :, :half] = x1 * cos - x2 * sin
        out[i, :, half:] = x1 * sin + x2 * cos
    return out


def masked_attention(q, k, v, visible):
    # q [nq, H, HD], k/v [nk, KH, HD]; visible(qi, kj) -> bool
    nq = q.shape[0]
    nk = k.shape[0]
    out = np.zeros((nq, H, HD))
    lse = np.full((nq, H), -np.inf)
    scale = 1.0 / math.sqrt(HD)
    for i in range(nq):
        vis = [kj for kj in range(nk) if visible(i, kj)]
        if not vis:
            continue
        for hh in range(H):
            j = hh // G
            scores = np.array([q[i, hh] @ k[kj, j] for kj in vis]) * scale
            m = scores.max()
            w = np.exp(scores - m)
            denom = w.sum()
            acc = sum(wt * v[kj, j] for wt, kj in zip(w, vis))
            out[i, hh] = acc / denom
            lse[i, hh] = m + math.log(denom)
    return out, lse


def merge_partials(outs, lses):
    nq = outs[0].shape[0]
    merged = np.zeros_like(outs[0])
    for i in range(nq):
        for hh in range(H):
            m = max(l[i, hh] for l in lses)
            m_safe = m if np.isfinite(m) else 0.0
            denom = 0.0
            acc = np.zeros(HD)
            for o, l in zip(outs, lses):
                if not np.isfinite(l[i, hh]):
                    continue
                w = math.exp(l[i, hh] - m_safe)
                denom += w
                acc += w * o[i, hh]
            merged[i, hh] = acc / (denom if denom > 0 else 1.0)
    return merged


def silu(x):
    return x / (1.0 + np.exp(-x))


def project_qkv(lw, hidden):
    x = rmsnorm(hidden)
    n = hidden.shape[0]
    q = (x @ lw["wq"]).reshape(n, H, HD)
    k = (x @ lw["wk"]).reshape(n, KH, HD)
    v = (x @ lw["wv"]).reshape(n, KH, HD)
    return q, k, v


def attn_tail(lw, hidden, att):
    n = hidden.shape[0]
    proj = att.reshape(n, H * HD) @ lw["wo"]
    h = hidden + proj
    x = rmsnorm(h)
    act = silu(x @ lw["w_gate"]) * (x @ lw["w_up"])
    return h + act @ lw["w_down"]


def lm_head(lm_head_w, hidden):
    return rmsnorm(hidden) @ lm_head_w


def ring_positions(rank):
    if rank == 0:
        return list(range(LQ + LB))
    start = LQ + rank * LB
    return list(range(start, start + LB))


def attn_partial(lw_unused, q, k, v, q_pos, k_pos):
    return masked_attention(q, k, v, lambda qi, kj: k_pos[kj] <= q_pos[qi])


def dense_run(embed, lm_head_w, layers, doc, query):
    tokens = query + doc
    n = len(tokens)
    positions = list(range(n))
    hidden = embed[tokens]
    caches = []  # per layer (k, v)
    for lw in layers:
        q, k, v = project_qkv(lw, hidden)
        q = rope(q, positions)
        k = rope(k, positions)
        att, _ = attn_partial(lw, q, k, v, positions, positions)
        hidden = attn_tail(lw, hidden, att)
        caches.append([k, v])
    # chunk decode (dense path: append then self-causal attend)
    pos0 = LQ + DOC_LEN
    cpos = list(range(pos0, pos0 + LQ))
    hc = embed[query]
    for li, lw in enumerate(layers):
        q, k, v = project_qkv(lw, hc)
        q = rope(q, cpos)
        k = rope(k, cpos)
        ck = np.concatenate([caches[li][0], k])
        cv = np.concatenate([caches[li][1], v])
        cache_len = ck.shape[0]
        nch = len(cpos)
        att, _ = masked_attention(
            q, ck, cv, lambda qi, kj: kj < cache_len - (nch - 1 - qi))
        hc = attn_tail(lw, hc, att)
    return lm_head(lm_head_w, hc)


def ring_run(embed, lm_head_w, layers, doc, query):
    tokens_by_host = []
    for r in range(HOSTS):
        if r == 0:
            tokens_by_host.append(query + doc[:LB])
        else:
            tokens_by_host.append(doc[r * LB:(r + 1) * LB])
    hiddens = [embed[t] for t in tokens_by_host]
    positions = [ring_positions(r) for r in range(HOSTS)]
    caches = [[] for _ in range(HOSTS)]  # per host, per layer (k, v)
    for lw in layers:
        qkv = []
        for r in range(HOSTS):
            q, k, v = project_qkv(lw, hiddens[r])
            q = rope(q, positions[r])
            k = rope(k, positions[r])
            qkv.append((q, k, v))
        for r in range(HOSTS):
            q, k, v = qkv[r]
            outs, lses = [], []
            o, l = attn_partial(lw, q, k, v, positions[r], positions[r])
            outs.append(o)
            lses.append(l)
            # ring rotation: origins (r - s) mod H for s = 1..H-1,
            # skipping origins > r (fully masked)
            for s in range(1, HOSTS):
                origin = (r + HOSTS - s) % HOSTS
                if origin < r:
                    ko, vo = qkv[origin][1], qkv[origin][2]
                    o, l = attn_partial(lw, q, ko, vo,
                                        positions[r], positions[origin])
                    outs.append(o)
                    lses.append(l)
            att = merge_partials(outs, lses)
            hiddens[r] = attn_tail(lw, hiddens[r], att)
            caches[r].append([k, v])
    # distributed chunk decode
    pos0 = LQ + DOC_LEN
    cpos = list(range(pos0, pos0 + LQ))
    hc = [embed[query] for _ in range(HOSTS)]
    last = HOSTS - 1
    nch = len(cpos)
    for li, lw in enumerate(layers):
        partials = []
        # all hosts compute the same (q,k,v) since hidden is replicated
        for r in range(HOSTS):
            q, k, v = project_qkv(lw, hc[r])
            q = rope(q, cpos)
            k = rope(k, cpos)
            if r == last:
                caches[r][li][0] = np.concatenate([caches[r][li][0], k])
                caches[r][li][1] = np.concatenate([caches[r][li][1], v])
                cache_len = caches[r][li][0].shape[0]
                o, l = masked_attention(
                    q, caches[r][li][0], caches[r][li][1],
                    lambda qi, kj: kj < cache_len - (nch - 1 - qi))
            else:
                cache_len = caches[r][li][0].shape[0]
                o, l = masked_attention(
                    q, caches[r][li][0], caches[r][li][1],
                    lambda qi, kj: kj < cache_len)
            partials.append((o, l))
        att = merge_partials([p[0] for p in partials], [p[1] for p in partials])
        for r in range(HOSTS):
            hc[r] = attn_tail(lw, hc[r], att)
    return lm_head(lm_head_w, hc[last])


def test_ring_matches_dense_mirror():
    import random
    random.seed(11)
    doc = [random.randrange(1, VOCAB) for _ in range(DOC_LEN)]
    query = [random.randrange(1, VOCAB) for _ in range(LQ)]
    embed, lmw, layers = build_weights()
    dense = dense_run(embed, lmw, layers, doc, query)
    ring = ring_run(embed, lmw, layers, doc, query)
    diff = np.abs(dense - ring).max()
    print(f"chunk logits Linf(ring, dense) = {diff:.3e}")
    assert diff < 1e-9, "ring != dense"
    # Sanity: logits are not degenerate (a collapsed pipeline would
    # trivially "agree").
    assert dense.max() - dense.min() > 0.5
    print("OK: RingAttn pipeline reproduces the Dense oracle")


if __name__ == "__main__":
    test_ring_matches_dense_mirror()
