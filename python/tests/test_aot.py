"""AOT pipeline: lowering round-trips, manifest integrity, weight blob
layout, and HLO re-execution of a lowered stage against the python fn."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot
from compile import model as M


@pytest.fixture(scope="module")
def built(test_cfg, tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    aot.build(test_cfg, out, train_steps=0, golden=True, golden_new=2,
              verbose=False)
    with open(os.path.join(out, "manifest.json")) as f:
        manifest = json.load(f)
    return out, manifest


def test_manifest_lists_all_artifacts(built, test_cfg):
    out, manifest = built
    expected = set(aot.stage_functions(test_cfg))
    assert set(manifest["artifacts"]) == expected
    for name, meta in manifest["artifacts"].items():
        path = os.path.join(out, meta["file"])
        assert os.path.exists(path), name
        text = open(path).read()
        assert text.startswith("HloModule"), name


def test_weights_blob_roundtrip(built, test_cfg, test_params):
    out, manifest = built
    blob = open(os.path.join(out, "weights.bin"), "rb").read()
    entries = manifest["weights"]["entries"]
    names = [e["name"] for e in entries]
    assert names == list(M.param_shapes(test_cfg))
    total = sum(e["size"] for e in entries)
    assert len(blob) == total
    for e in entries:
        arr = np.frombuffer(blob, "<f4", count=int(np.prod(e["shape"])),
                            offset=e["offset"]).reshape(e["shape"])
        # aot.build re-inits with the same seed -> identical weights.
        np.testing.assert_allclose(arr, np.asarray(test_params[e["name"]]),
                                   atol=0)


def test_golden_entries_present(built):
    out, manifest = built
    golden = manifest["golden"]
    assert golden is not None
    names = {e["name"] for e in golden["entries"]}
    assert {"doc_tokens", "query_tokens", "generated", "query_logits",
            "host0_hidden", "hostH_hidden"} <= names
    blob = open(os.path.join(out, "golden.bin"), "rb").read()
    assert len(blob) == sum(e["size"] for e in golden["entries"])


def test_config_derived_fields(built, test_cfg):
    _, manifest = built
    derived = manifest["config"]["derived"]
    assert derived["n_tot"] == test_cfg.apb.n_tot
    assert derived["pass_max"] == test_cfg.apb.pass_max
    assert derived["cache_max"] == test_cfg.apb.cache_max


def _run_hlo(path, inputs):
    """Compile + execute an HLO text artifact with the python CPU client —
    the same round-trip the rust runtime does through PJRT."""
    text = open(path).read()
    comp = xc._xla.hlo_module_from_text(text)
    client = jax.devices("cpu")[0].client
    exe = client.compile(
        xc._xla.XlaComputation(comp.as_serialized_hlo_module_proto())
        .as_serialized_hlo_module_proto()
        if False else
        xc._xla.XlaComputation(comp.as_serialized_hlo_module_proto()))
    bufs = [client.buffer_from_pyval(np.ascontiguousarray(x))
            for x in inputs]
    out = exe.execute(bufs)
    return [np.asarray(o) for o in out]


def test_lowered_lm_head_matches_python(built, test_cfg, test_params):
    """Execute one lowered artifact through the XLA client and compare to
    the python stage function (the py-side twin of the rust runtime test)."""
    out, manifest = built
    meta = manifest["artifacts"]["lm_head_step"]
    hidden = np.random.default_rng(0).normal(
        size=(1, test_cfg.model.d_model)).astype(np.float32)
    want = np.asarray(M.lm_head(jnp.asarray(hidden),
                                test_params["final_norm"],
                                test_params["lm_head"], test_cfg))
    try:
        got = _run_hlo(os.path.join(out, meta["file"]),
                       [hidden, np.asarray(test_params["final_norm"]),
                        np.asarray(test_params["lm_head"])])
    except Exception as e:  # pragma: no cover - client API drift
        pytest.skip(f"python XLA client execution unavailable: {e}")
    np.testing.assert_allclose(got[0], want, atol=1e-4, rtol=1e-4)


def test_stage_functions_shapes_consistent(test_cfg):
    """Every artifact's recorded output shapes re-derive from its inputs."""
    stages = aot.stage_functions(test_cfg)
    a, m = test_cfg.apb, test_cfg.model
    pre = stages["layer_pre"][1]
    by_name = dict(pre)
    assert tuple(by_name["hidden"].shape) == (a.n_tot, m.d_model)
    post = dict(stages["layer_post"][1])
    assert tuple(post["k_pass"].shape) == (a.pass_max, m.n_kv_heads,
                                           m.head_dim)
    att = dict(stages["decode_attn_step"][1])
    assert tuple(att["k_cache"].shape) == (a.cache_max, m.n_kv_heads,
                                           m.head_dim)
