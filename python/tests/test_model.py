"""L2 model stage functions: shapes, H=1 exactness, ablation semantics,
decode equivalence, position layout."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.configs import ApbConfig, Config
from compile import model as M
from compile.kernels import ref as kref


@pytest.fixture(scope="module")
def io(test_cfg, rng):
    doc = rng.integers(1, test_cfg.model.vocab_size,
                       test_cfg.apb.doc_len).astype(np.int32)
    query = rng.integers(1, test_cfg.model.vocab_size,
                         test_cfg.apb.query_len).astype(np.int32)
    return doc, query


def test_param_shapes_cover_all(test_cfg, test_params):
    shapes = M.param_shapes(test_cfg)
    assert set(shapes) == set(test_params)
    for name, shp in shapes.items():
        assert tuple(test_params[name].shape) == shp, name


def test_rmsnorm_unit_scale():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(5, 16)),
                    jnp.float32)
    y = M.rmsnorm(x, jnp.ones(16), 1e-6)
    rms = np.sqrt((np.asarray(y) ** 2).mean(-1))
    np.testing.assert_allclose(rms, 1.0, atol=1e-3)


def test_rope_preserves_norm_and_relative_dot():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(6, 2, 8)), jnp.float32)
    pos = jnp.arange(6, dtype=jnp.int32)
    y = M.rope(x, pos, 10000.0)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               atol=1e-4)
    # Relative property: <rope(q,i), rope(k,j)> depends only on i-j.
    q = jnp.asarray(rng.normal(size=(1, 1, 8)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 1, 8)), jnp.float32)
    def dot(i, j):
        qi = M.rope(q, jnp.asarray([i], jnp.int32), 10000.0)
        kj = M.rope(k, jnp.asarray([j], jnp.int32), 10000.0)
        return float(jnp.sum(qi * kj))
    assert abs(dot(5, 3) - dot(9, 7)) < 1e-4
    assert abs(dot(5, 3) - dot(5, 2)) > 1e-6


def test_prefill_shapes(test_cfg, test_params, io):
    doc, query = io
    caches, hiddens = M.run_apb_prefill(test_params, test_cfg, doc, query)
    a, m = test_cfg.apb, test_cfg.model
    assert len(caches) == a.n_hosts
    assert len(caches[0]) == m.n_layers
    k0, v0 = caches[0][0]
    assert k0.shape == (a.block_len, m.n_kv_heads, m.head_dim)
    for h in hiddens:
        assert h.shape == (a.n_tot, m.d_model)
        assert np.isfinite(np.asarray(h)).all()


def test_h1_apb_equals_exact_reference(test_cfg, rng):
    """With a single host there is no anchor, no passing, no compression:
    APB degenerates to exact causal attention (paper Limitations)."""
    cfg1 = Config(name="h1", model=test_cfg.model,
                  apb=ApbConfig(n_hosts=1, block_len=48, anchor_len=8,
                                query_len=4, passing_len=8,
                                max_new_tokens=8))
    params = M.init_params(cfg1)
    doc = rng.integers(1, cfg1.model.vocab_size,
                       cfg1.apb.doc_len).astype(np.int32)
    query = rng.integers(1, cfg1.model.vocab_size,
                         cfg1.apb.query_len).astype(np.int32)
    c_apb, h_apb = M.run_apb_prefill(params, cfg1, doc, query)
    c_ref, h_ref = M.run_exact_reference(params, cfg1, doc, query, 0)
    l_aq = cfg1.apb.l_aq
    for li in range(cfg1.model.n_layers):
        np.testing.assert_allclose(np.asarray(c_apb[0][li][0]),
                                   np.asarray(c_ref[li][0]), atol=1e-4)
        np.testing.assert_allclose(np.asarray(c_apb[0][li][1]),
                                   np.asarray(c_ref[li][1]), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_apb[0][l_aq:]),
                               np.asarray(h_ref), atol=1e-3, rtol=1e-3)


def test_anchor_ablation_changes_outputs(test_cfg, test_params, io):
    doc, query = io
    base, _ = M.run_apb_prefill(test_params, test_cfg, doc, query)
    no_anchor, _ = M.run_apb_prefill(test_params, test_cfg, doc, query,
                                     M.ApbOptions(use_anchor=False))
    # Host 0 has no anchor either way -> its layer-0 KV is identical.
    np.testing.assert_allclose(np.asarray(base[0][0][0]),
                               np.asarray(no_anchor[0][0][0]), atol=1e-6)
    # Host 1 must differ (its local block saw the anchor).
    d = np.abs(np.asarray(base[1][1][0]) -
               np.asarray(no_anchor[1][1][0])).max()
    assert d > 1e-4


def test_passing_ablation_changes_outputs(test_cfg, test_params, io):
    doc, query = io
    base, _ = M.run_apb_prefill(test_params, test_cfg, doc, query)
    no_pass, _ = M.run_apb_prefill(test_params, test_cfg, doc, query,
                                   M.ApbOptions(method="star"))
    # Host 0 never receives passing blocks -> unchanged.
    np.testing.assert_allclose(np.asarray(base[0][-1][0]),
                               np.asarray(no_pass[0][-1][0]), atol=1e-6)
    d = np.abs(np.asarray(base[-1][-1][0]) -
               np.asarray(no_pass[-1][-1][0])).max()
    assert d > 1e-4


def test_method_string_is_validated():
    # The python mirror speaks the rust AttnMethod spellings; the exact
    # baselines (ring/dense) are rust-cluster-only and must be rejected
    # here rather than silently treated as "no passing".
    assert M.ApbOptions().method == "apb"
    assert M.ApbOptions(method="star").method == "star"
    with pytest.raises(ValueError):
        M.ApbOptions(method="ring")
    with pytest.raises(ValueError):
        M.ApbOptions(method="use_passing")


def test_random_compressor_differs_from_retaining(test_cfg, test_params, io):
    doc, query = io
    base, _ = M.run_apb_prefill(test_params, test_cfg, doc, query)
    rd, _ = M.run_apb_prefill(test_params, test_cfg, doc, query,
                              M.ApbOptions(compressor="random"))
    d = np.abs(np.asarray(base[-1][-1][0]) - np.asarray(rd[-1][-1][0])).max()
    assert d > 1e-5


def test_embed_query_ablation(test_cfg, io):
    doc, query = io
    t_with = M.host_tokens(test_cfg, doc, query, 1, M.ApbOptions())
    t_without = M.host_tokens(test_cfg, doc, query, 1,
                              M.ApbOptions(embed_query=False))
    lq = test_cfg.apb.query_len
    assert (t_with[:lq] == query).all()
    assert (t_without[:lq] == 0).all()
    np.testing.assert_array_equal(t_with[lq:], t_without[lq:])


def test_host0_tokens_have_no_anchor(test_cfg, io):
    doc, query = io
    t0 = M.host_tokens(test_cfg, doc, query, 0, M.ApbOptions())
    assert (t0[:test_cfg.apb.l_aq] == 0).all()
    np.testing.assert_array_equal(t0[test_cfg.apb.l_aq:],
                                  doc[:test_cfg.apb.block_len])
    assert M.n_anchor_for(test_cfg, 0, M.ApbOptions()) == 0
    assert M.n_anchor_for(test_cfg, 1, M.ApbOptions()) == test_cfg.apb.l_aq


def test_decode_generates_and_is_deterministic(test_cfg, test_params, io):
    doc, query = io
    caches, _ = M.run_apb_prefill(test_params, test_cfg, doc, query)
    gen1, logits1 = M.run_decode(test_params, test_cfg, caches, query, 3)
    gen2, logits2 = M.run_decode(test_params, test_cfg, caches, query, 3)
    np.testing.assert_array_equal(gen1, gen2)
    np.testing.assert_allclose(logits1, logits2, atol=0)
    assert gen1.shape == (3,)
    assert np.isfinite(logits1).all()


def test_decode_matches_monolithic_attention(test_cfg, test_params, io):
    """The distributed decode (per-host partials + LSE merge) must equal a
    single attention over the concatenated caches — exactness of
    Algorithm 3."""
    doc, query = io
    a, m = test_cfg.apb, test_cfg.model
    caches, _ = M.run_apb_prefill(test_params, test_cfg, doc, query)

    # Distributed: one layer, one step, via the pipeline pieces.
    lp = M.layer_params(test_params, 0)
    hidden = M.embed(jnp.asarray(query[:1]), test_params["embed"])
    pos0 = a.query_len + a.doc_len
    q, k, v = M.decode_pre(hidden, lp, pos0, test_cfg)

    outs, lses = [], []
    k_all, v_all = [], []
    for h in range(a.n_hosts):
        kc, vc = caches[h][0]
        if h == a.n_hosts - 1:
            kfull = jnp.concatenate([kc, k])
            vfull = jnp.concatenate([vc, v])
        else:
            kfull, vfull = kc, vc
        o, s = kref.attention_ref(q, kfull, vfull,
                                  jnp.ones((1, kfull.shape[0]), bool))
        outs.append(o)
        lses.append(s)
        k_all.append(kfull)
        v_all.append(vfull)
    merged, _ = kref.merge_partials_ref(outs, lses)
    mono, _ = kref.attention_ref(
        q, jnp.concatenate(k_all), jnp.concatenate(v_all),
        jnp.ones((1, sum(x.shape[0] for x in k_all)), bool))
    np.testing.assert_allclose(np.asarray(merged), np.asarray(mono),
                               atol=1e-5)
