"""Python mirror of the chunked-prefill state machines in
rust/src/coordinator/prefill.rs, verifying the BIT-IDENTITY invariant of
docs/ADR-002-chunked-prefill.md independently of the Rust toolchain:
for any chunk partition, the chunked execution order must reproduce the
monolithic prefill — same hidden states, same KV caches, same logits.

Mirrors the three machine shapes exactly as the Rust plans execute them:

* APB (layer-major): per layer, anchor + local chunks through
  projection/RoPE/scores (`ApbPre`), then top-l_p select + passing-block
  exchange (`ApbGather`), then per-chunk modified-mask attention at the
  chunk's absolute row offset (`ApbPost`);
* Ring (layer-major, pipelined rotation): per-chunk partials of the own
  block, then of each received block in rotation order, merged per chunk;
* Dense (chunk-major): each chunk of `[query | doc]` rows through every
  layer against the running KV cache.

f64 throughout — this checks the ALGORITHM (chunk row offsets, anchor
handling, selection over assembled scores, partial ordering), not f32
rounding; the Rust proptest `chunked_prefill.rs` pins exact f32 equality.

Runs standalone (`python3 test_chunked_prefill_mirror.py`, numpy only) or
under pytest alongside the jax-based suite."""
import math
import random

import numpy as np

from test_ring_dense_mirror import (
    DOC_LEN, H, HD, HOSTS, KH, L, LA, LB, LP, LQ, VOCAB,
    attn_partial, attn_tail, build_weights, dense_run, lm_head,
    masked_attention, merge_partials, project_qkv, ring_positions,
    ring_run, rope,
)

LAQ = LQ + LA
PASS_MAX = (HOSTS - 1) * LP
SCALE = 1.0 / math.sqrt(HD)


def gelu(x):
    c = math.sqrt(2.0 / math.pi)
    return 0.5 * x * (1.0 + np.tanh(c * (x + 0.044715 * x ** 3)))


def chunk_ranges(rows, ct, n_chunks):
    return [(min(c * ct, rows), min((c + 1) * ct, rows)) for c in range(n_chunks)]


def apb_host_tokens(doc, query, rank):
    anchor = [0] * LAQ
    if rank > 0:
        anchor[:LQ] = query
        anchor[LQ:] = doc[:LA]
    return anchor + doc[rank * LB:(rank + 1) * LB]


def apb_positions(rank):
    pos_offset = LQ + rank * LB
    return list(range(LAQ)) + [pos_offset + i for i in range(LB)]


def retaining_scores(q_nr_query, q_nr_rows, k_nr_rows):
    """The crafted sim compressor (runtime/sim.rs): hidden unit 0 reads the
    sim_max feature shifted by +3 into gelu's monotone region, the output
    reads hidden 0 — score(i, j) = gelu(smax(i, j) + 3)."""
    w = q_nr_query.shape[0]
    qq = q_nr_query.reshape(w, KH, H // KH, HD).mean(axis=2)  # group mean
    n = q_nr_rows.shape[0]
    scores = np.zeros((n, KH))
    for i in range(n):
        for j in range(KH):
            smax = max(float(qq[wi, j] @ k_nr_rows[i, j]) * SCALE
                       for wi in range(w))
            scores[i, j] = gelu(smax + 3.0)
    return scores


def top_lp(scores):
    """Per-head top-LP, ties broken toward lower index, ascending output."""
    n = scores.shape[0]
    out = []
    for j in range(KH):
        order = sorted(range(n), key=lambda i: (-scores[i, j], i))
        out.append(sorted(order[:LP]))
    return out


def gather_compressed(k_local, v_local, idx):
    kc = np.zeros((LP, KH, HD))
    vc = np.zeros((LP, KH, HD))
    for j in range(KH):
        for t, i in enumerate(idx[j]):
            kc[t, j] = k_local[i, j]
            vc[t, j] = v_local[i, j]
    return kc, vc


def apb_visible(n_anchor, pass_len, qi, kj):
    if qi < LAQ:
        return kj < LAQ and kj <= qi
    if kj < LAQ:
        return kj < n_anchor
    if kj < LAQ + PASS_MAX:
        return kj - LAQ < pass_len
    return kj - LAQ - PASS_MAX <= qi - LAQ


def apb_layer_exchange(layer_pre_out):
    """Per-layer select + AllGather + assembly, shared by both shapes.
    layer_pre_out[r] = (q, k, v, scores) for host r's full layout rows."""
    compressed = []
    for r in range(HOSTS):
        _, k, v, scores = layer_pre_out[r]
        idx = top_lp(scores)
        compressed.append(gather_compressed(k[LAQ:], v[LAQ:], idx))
    passes = []
    for r in range(HOSTS):
        k_pass = np.zeros((PASS_MAX, KH, HD))
        v_pass = np.zeros((PASS_MAX, KH, HD))
        for g in range(r):
            k_pass[g * LP:(g + 1) * LP] = compressed[g][0]
            v_pass[g * LP:(g + 1) * LP] = compressed[g][1]
        passes.append((k_pass, v_pass, r * LP))
    return passes


def apb_run_monolithic(embed, lm_head_w, layers, doc, query):
    """The pre-chunking host.rs prefill_apb order: full-layout layer_pre,
    select+gather, full-layout layer_post, per layer."""
    hiddens = [embed[apb_host_tokens(doc, query, r)] for r in range(HOSTS)]
    positions = [apb_positions(r) for r in range(HOSTS)]
    caches = [[] for _ in range(HOSTS)]
    for lw in layers:
        pre = []
        for r in range(HOSTS):
            q_nr, k_nr, v = project_qkv(lw, hiddens[r])
            scores = retaining_scores(q_nr[:LQ], q_nr[LAQ:], k_nr[LAQ:])
            q = rope(q_nr, positions[r])
            k = rope(k_nr, positions[r])
            pre.append((q, k, v, scores))
        passes = apb_layer_exchange(pre)
        for r in range(HOSTS):
            q, k, v, _ = pre[r]
            k_pass, v_pass, pass_len = passes[r]
            n_anchor = LAQ if r > 0 else 0
            k_attn = np.concatenate([k[:LAQ], k_pass, k[LAQ:]])
            v_attn = np.concatenate([v[:LAQ], v_pass, v[LAQ:]])
            att, _ = masked_attention(
                q, k_attn, v_attn,
                lambda qi, kj: apb_visible(n_anchor, pass_len, qi, kj))
            hiddens[r] = attn_tail(lw, hiddens[r], att)
            caches[r].append([k[LAQ:], v[LAQ:]])
    return hiddens, caches


def apb_run_chunked(embed, lm_head_w, layers, doc, query, ct):
    """The PrefillMachine order: per layer, ApbPre×C (anchor rows at chunk
    0, per-chunk projection/scores), ApbGather, ApbPost×C (row-offset
    attention + per-chunk cache append)."""
    n_chunks = -(-LB // ct)  # ceil
    chunks = chunk_ranges(LB, ct, n_chunks)
    hiddens = [embed[apb_host_tokens(doc, query, r)] for r in range(HOSTS)]
    caches = [[] for _ in range(HOSTS)]
    for lw in layers:
        pre = []
        for r in range(HOSTS):
            pos_offset = LQ + r * LB
            q = np.zeros((LAQ + LB, H, HD))
            k = np.zeros((LAQ + LB, KH, HD))
            v = np.zeros((LAQ + LB, KH, HD))
            scores = np.zeros((LB, KH))
            for ci, (c0, c1) in enumerate(chunks):
                if ci == 0:  # anchor rows ride chunk 0 (Op::ApbPre c == 0)
                    qa, ka, va = project_qkv(lw, hiddens[r][:LAQ])
                    q[:LAQ] = rope(qa, list(range(LAQ)))
                    k[:LAQ] = rope(ka, list(range(LAQ)))
                    v[:LAQ] = va
                # layer_pre_chunk: anchor-query projection + chunk rows
                q_nr_query, _, _ = project_qkv(lw, hiddens[r][:LQ])
                q_nr, k_nr, vc = project_qkv(lw, hiddens[r][LAQ + c0:LAQ + c1])
                scores[c0:c1] = retaining_scores(q_nr_query, q_nr, k_nr)
                pos = [pos_offset + i for i in range(c0, c1)]
                q[LAQ + c0:LAQ + c1] = rope(q_nr, pos)
                k[LAQ + c0:LAQ + c1] = rope(k_nr, pos)
                v[LAQ + c0:LAQ + c1] = vc
            pre.append((q, k, v, scores))
        passes = apb_layer_exchange(pre)
        for r in range(HOSTS):
            q, k, v, _ = pre[r]
            k_pass, v_pass, pass_len = passes[r]
            n_anchor = LAQ if r > 0 else 0
            k_attn = np.concatenate([k[:LAQ], k_pass, k[LAQ:]])
            v_attn = np.concatenate([v[:LAQ], v_pass, v[LAQ:]])
            layer_k, layer_v = [], []
            for ci, (c0, c1) in enumerate(chunks):
                row0, row1 = (0, LAQ + c1) if ci == 0 else (LAQ + c0, LAQ + c1)
                att, _ = masked_attention(
                    q[row0:row1], k_attn, v_attn,
                    lambda qi, kj: apb_visible(n_anchor, pass_len, qi + row0, kj))
                hiddens[r][row0:row1] = attn_tail(lw, hiddens[r][row0:row1], att)
                layer_k.append(k[LAQ + c0:LAQ + c1])
                layer_v.append(v[LAQ + c0:LAQ + c1])
            caches[r].append([np.concatenate(layer_k), np.concatenate(layer_v)])
    return hiddens, caches


def apb_chunk_decode(layers, lm_head_w, embed, caches, query):
    """Distributed query-chunk decode over the prefilled caches (same for
    both shapes; mirrors the ring mirror's decode)."""
    pos0 = LQ + DOC_LEN
    cpos = list(range(pos0, pos0 + LQ))
    hc = [embed[query] for _ in range(HOSTS)]
    last = HOSTS - 1
    nch = len(cpos)
    for li, lw in enumerate(layers):
        partials = []
        for r in range(HOSTS):
            q, k, v = project_qkv(lw, hc[r])
            q = rope(q, cpos)
            k = rope(k, cpos)
            if r == last:
                caches[r][li][0] = np.concatenate([caches[r][li][0], k])
                caches[r][li][1] = np.concatenate([caches[r][li][1], v])
                clen = caches[r][li][0].shape[0]
                o, l = masked_attention(
                    q, caches[r][li][0], caches[r][li][1],
                    lambda qi, kj: kj < clen - (nch - 1 - qi))
            else:
                clen = caches[r][li][0].shape[0]
                o, l = masked_attention(
                    q, caches[r][li][0], caches[r][li][1],
                    lambda qi, kj: kj < clen)
            partials.append((o, l))
        att = merge_partials([p[0] for p in partials], [p[1] for p in partials])
        for r in range(HOSTS):
            hc[r] = attn_tail(lw, hc[r], att)
    return lm_head(lm_head_w, hc[last])


def ring_run_chunked(embed, lm_head_w, layers, doc, query, ct):
    """The RingMachine order: per layer, RingPre×C, then partials of the
    own block ×C, then each received block in rotation order ×C (the
    pipelined exchange only reorders communication, not arithmetic), then
    per-chunk merge + attn_tail (RingTail), then append."""
    tokens = [query + doc[:LB]] + \
             [doc[r * LB:(r + 1) * LB] for r in range(1, HOSTS)]
    hiddens = [embed[t] for t in tokens]
    positions = [ring_positions(r) for r in range(HOSTS)]
    max_rows = LQ + LB
    n_chunks = -(-max_rows // ct)
    caches = [[] for _ in range(HOSTS)]
    for lw in layers:
        qkv = []
        for r in range(HOSTS):
            rows = len(positions[r])
            chunks = chunk_ranges(rows, ct, n_chunks)
            q = np.zeros((rows, H, HD))
            k = np.zeros((rows, KH, HD))
            v = np.zeros((rows, KH, HD))
            for c0, c1 in chunks:
                if c0 == c1:
                    continue
                qc, kc, vc = project_qkv(lw, hiddens[r][c0:c1])
                q[c0:c1] = rope(qc, positions[r][c0:c1])
                k[c0:c1] = rope(kc, positions[r][c0:c1])
                v[c0:c1] = vc
            qkv.append((q, k, v))
        for r in range(HOSTS):
            rows = len(positions[r])
            chunks = chunk_ranges(rows, ct, n_chunks)
            q, k, v = qkv[r]
            outs, lses = [], []
            # RingPartial s = 0..H-1 in plan order, chunked q rows.
            for s in range(HOSTS):
                origin = (r + HOSTS - s) % HOSTS
                if s > 0 and origin >= r:
                    continue
                o = np.zeros((rows, H, HD))
                l = np.zeros((rows, H))
                ko, vo = (k, v) if s == 0 else (qkv[origin][1], qkv[origin][2])
                kpos = positions[r] if s == 0 else positions[origin]
                for c0, c1 in chunks:
                    if c0 == c1:
                        continue
                    oc, lc = attn_partial(lw, q[c0:c1], ko, vo,
                                          positions[r][c0:c1], kpos)
                    o[c0:c1] = oc
                    l[c0:c1] = lc
                outs.append(o)
                lses.append(l)
            # RingTail: merge + decode_post per chunk.
            for c0, c1 in chunks:
                if c0 == c1:
                    continue
                att = merge_partials([o[c0:c1] for o in outs],
                                     [l[c0:c1] for l in lses])
                hiddens[r][c0:c1] = attn_tail(lw, hiddens[r][c0:c1], att)
            caches[r].append([k, v])
    return apb_chunk_decode(layers, lm_head_w, embed, caches, query)


def dense_run_chunked(embed, lm_head_w, layers, doc, query, ct):
    """The DenseMachine order: chunk-major — each chunk of [query | doc]
    rows through every layer against the running KV (concat cache prefix +
    own rows, position-causal)."""
    tokens = query + doc
    rows = len(tokens)
    n_chunks = -(-rows // ct)
    caches = [[np.zeros((0, KH, HD)), np.zeros((0, KH, HD))] for _ in range(L)]
    for c0, c1 in chunk_ranges(rows, ct, n_chunks):
        hidden = embed[tokens[c0:c1]]
        pos_chunk = list(range(c0, c1))
        for li, lw in enumerate(layers):
            q, k, v = project_qkv(lw, hidden)
            q = rope(q, pos_chunk)
            k = rope(k, pos_chunk)
            k_vis = np.concatenate([caches[li][0], k])
            v_vis = np.concatenate([caches[li][1], v])
            att, _ = attn_partial(lw, q, k_vis, v_vis,
                                  pos_chunk, list(range(c1)))
            hidden = attn_tail(lw, hidden, att)
            caches[li][0] = k_vis
            caches[li][1] = v_vis
    # Dense query-chunk decode on "host 0" (append then self-causal).
    pos0 = LQ + DOC_LEN
    cpos = list(range(pos0, pos0 + LQ))
    hc = embed[query]
    nch = len(cpos)
    for li, lw in enumerate(layers):
        q, k, v = project_qkv(lw, hc)
        q = rope(q, cpos)
        k = rope(k, cpos)
        ck = np.concatenate([caches[li][0], k])
        cv = np.concatenate([caches[li][1], v])
        clen = ck.shape[0]
        att, _ = masked_attention(
            q, ck, cv, lambda qi, kj: kj < clen - (nch - 1 - qi))
        hc = attn_tail(lw, hc, att)
    return lm_head(lm_head_w, hc)


TOL = 1e-9
CHUNK_SIZES = [1, 5, LB, LB + 7, DOC_LEN + 1]


def _request(seed=23):
    random.seed(seed)
    doc = [random.randrange(1, VOCAB) for _ in range(DOC_LEN)]
    query = [random.randrange(1, VOCAB) for _ in range(LQ)]
    return doc, query


def test_apb_chunked_matches_monolithic():
    doc, query = _request()
    embed, lmw, layers = build_weights()
    h_ref, c_ref = apb_run_monolithic(embed, lmw, layers, doc, query)
    logits_ref = apb_chunk_decode(
        layers, lmw, embed, [[list(kv) for kv in c] for c in c_ref], query)
    assert logits_ref.max() - logits_ref.min() > 0.5, "degenerate pipeline"
    for ct in CHUNK_SIZES:
        h, c = apb_run_chunked(embed, lmw, layers, doc, query, ct)
        for r in range(HOSTS):
            dh = max(np.abs(h[r] - h_ref[r]).max(), 0.0)
            assert dh < TOL, f"ct={ct} host {r}: hidden Linf {dh:.3e}"
            for li in range(L):
                dk = np.abs(c[r][li][0] - c_ref[r][li][0]).max()
                dv = np.abs(c[r][li][1] - c_ref[r][li][1]).max()
                assert max(dk, dv) < TOL, f"ct={ct} host {r} layer {li}: KV diff"
        logits = apb_chunk_decode(
            layers, lmw, embed, [[list(kv) for kv in cc] for cc in c], query)
        d = np.abs(logits - logits_ref).max()
        print(f"APB ct={ct}: logits Linf {d:.3e}")
        assert d < TOL


def test_ring_chunked_matches_monolithic():
    doc, query = _request(29)
    embed, lmw, layers = build_weights()
    logits_ref = ring_run(embed, lmw, layers, doc, query)
    for ct in CHUNK_SIZES:
        logits = ring_run_chunked(embed, lmw, layers, doc, query, ct)
        d = np.abs(logits - logits_ref).max()
        print(f"Ring ct={ct}: logits Linf {d:.3e}")
        assert d < TOL


def test_dense_chunked_matches_monolithic():
    doc, query = _request(31)
    embed, lmw, layers = build_weights()
    logits_ref = dense_run(embed, lmw, layers, doc, query)
    for ct in CHUNK_SIZES:
        logits = dense_run_chunked(embed, lmw, layers, doc, query, ct)
        d = np.abs(logits - logits_ref).max()
        print(f"Dense ct={ct}: logits Linf {d:.3e}")
        assert d < TOL


if __name__ == "__main__":
    test_apb_chunked_matches_monolithic()
    test_ring_chunked_matches_monolithic()
    test_dense_chunked_matches_monolithic()
    print("OK: chunked prefill mirrors are bit-identical to monolithic")
