"""Mirror of rust/src/workload/mod.rs::generate over rust/src/util/rng.rs.

Replays the exact RNG draw sequence of the Rust trace generator (xoshiro256**
seeded through splitmix64, identical call order) for every named TraceSpec and
asserts the preconditions the serving smoke gates rely on:

* every named trace generates, arrivals are monotone;
* `smoke` and `adversarial` contain at least one block-scale long request
  (`apb serve --trace smoke --smoke` asserts `n_long >= 1`);
* under `--prefix-cache` the smoke trace produces at least one prefix HIT:
  some shared-corpus (doc, query) pair is used at least twice (the first
  admitted use is cold; the one-prefill-at-a-time permit serialises
  admissions, so every later use of the pair attaches warm);
* starvation headroom: an upper bound on total admission work (196 ticks per
  ct=1 long, 17 per short) stays below the default 1024-tick starvation
  budget for `smoke`, so the CI gate `starved == 0` cannot be violated by
  construction of the trace alone.

Stdlib-only, like the other mirrors (no numpy needed here).
"""

import math

M64 = (1 << 64) - 1


def splitmix64(x):
    x = (x + 0x9E3779B97F4A7C15) & M64
    z = x
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & M64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & M64
    return z ^ (z >> 31)


def _rotl(x, k):
    return ((x << k) | (x >> (64 - k))) & M64


class Rng:
    """xoshiro256** — bit-identical twin of rust/src/util/rng.rs::Rng."""

    def __init__(self, seed):
        s, x = [], seed & M64
        for _ in range(4):
            x = (x + 0x9E3779B97F4A7C15) & M64
            s.append(splitmix64(x))
        self.s = s

    def next_u64(self):
        s = self.s
        result = (_rotl((s[1] * 5) & M64, 7) * 9) & M64
        t = (s[1] << 17) & M64
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = _rotl(s[3], 45)
        return result

    def f64(self):
        return (self.next_u64() >> 11) / float(1 << 53)

    def below(self, n):
        return self.next_u64() % max(n, 1)

    def range(self, lo, hi):
        assert hi > lo
        return lo + self.below(hi - lo)

    def choice_weighted(self, weights):
        total = sum(weights)
        if total <= 0.0:
            return self.below(len(weights))
        target = self.f64() * total
        for i, w in enumerate(weights):
            target -= w
            if target <= 0.0:
                return i
        return len(weights) - 1


# --- sim_tiny geometry (rust/src/config/mod.rs::Config::sim_tiny) ----------
N_HOSTS, BLOCK_LEN, QUERY_LEN, VOCAB, N_LAYERS = 3, 32, 4, 128, 2
DOC_LEN = N_HOSTS * BLOCK_LEN

# --- named TraceSpecs (rust/src/workload/mod.rs::TraceSpec::by_name) --------
SPECS = {
    "smoke": dict(
        seed=0xAB5E, n_requests=8,
        arrival=("poisson", 2.0),
        long_fraction=0.2, long_chunk_tokens=1,
        short_max_new=(2, 4), long_max_new=(4, 8),
        prefix_hit_rate=0.5, corpus_size=2, class_weights=[0.5, 0.5, 0.0],
    ),
    "adversarial": dict(
        seed=0xBAD_F00D, n_requests=12,
        arrival=("bursty", 4, 16),
        long_fraction=0.34, long_chunk_tokens=1,
        short_max_new=(1, 3), long_max_new=(6, 10),
        prefix_hit_rate=0.25, corpus_size=2, class_weights=[0.6, 0.4, 0.0],
    ),
    "poisson": dict(
        seed=0x903507, n_requests=16,
        arrival=("poisson", 4.0),
        long_fraction=0.125, long_chunk_tokens=2,
        short_max_new=(2, 5), long_max_new=(6, 12),
        prefix_hit_rate=0.4, corpus_size=3, class_weights=[0.4, 0.5, 0.1],
    ),
    "bursty": dict(
        seed=0xB0257, n_requests=12,
        arrival=("bursty", 3, 32),
        long_fraction=0.25, long_chunk_tokens=2,
        short_max_new=(1, 4), long_max_new=(4, 8),
        prefix_hit_rate=0.3, corpus_size=2, class_weights=[0.3, 0.5, 0.2],
    ),
}


def random_tokens(rng, n):
    return [rng.range(1, VOCAB) for _ in range(n)]


def generate(spec):
    """Mirror of workload::generate — identical draw order."""
    rng = Rng(spec["seed"])
    corpus = [
        (tuple(random_tokens(rng, DOC_LEN)), tuple(random_tokens(rng, QUERY_LEN)))
        for _ in range(max(spec["corpus_size"], 1))
    ]
    arrivals, at_tick = [], 0
    for i in range(spec["n_requests"]):
        if i > 0:
            a = spec["arrival"]
            if a[0] == "poisson":
                u = max(rng.f64(), 1e-12)
                # f64::round ties away from zero == round-half-up for
                # positive values (math.floor(x + 0.5)).
                at_tick += int(math.floor(-math.log(u) * a[1] + 0.5))
            else:
                _, burst, gap = a
                if i % max(burst, 1) == 0:
                    at_tick += gap
        long = rng.f64() < spec["long_fraction"]
        if long:
            lo, hi = spec["long_max_new"]
            max_new = rng.range(lo, hi + 1)
            doc = tuple(random_tokens(rng, DOC_LEN))
            query = tuple(random_tokens(rng, QUERY_LEN))
            arrivals.append(dict(at=at_tick, long=True, cls="batch",
                                 max_new=max_new, pair=None))
        else:
            cls = ["interactive", "standard", "batch"][
                rng.choice_weighted(spec["class_weights"])]
            lo, hi = spec["short_max_new"]
            max_new = rng.range(lo, hi + 1)
            shares = rng.f64() < spec["prefix_hit_rate"]
            if shares:
                pair = rng.below(len(corpus))
            else:
                pair = None
                random_tokens(rng, DOC_LEN)
                random_tokens(rng, QUERY_LEN)
            arrivals.append(dict(at=at_tick, long=False, cls=cls,
                                 max_new=max_new, pair=pair))
    return arrivals


def apb_plan_len(chunk_tokens):
    """APB plan length (rust prefill.rs::apb_plan): L * (3C + 2), C > 1."""
    n_chunks = (BLOCK_LEN + chunk_tokens - 1) // chunk_tokens
    per_layer = 5 if n_chunks == 1 else 3 * n_chunks + 2
    return N_LAYERS * per_layer


def main():
    for name, spec in SPECS.items():
        tr = generate(spec)
        assert len(tr) == spec["n_requests"], name
        ticks = [r["at"] for r in tr]
        assert ticks == sorted(ticks), f"{name}: arrivals not monotone"
        n_long = sum(r["long"] for r in tr)
        pair_uses = {}
        for r in tr:
            if r["pair"] is not None:
                pair_uses[r["pair"]] = pair_uses.get(r["pair"], 0) + 1
        hits = sum(c - 1 for c in pair_uses.values())
        # Admission-work upper bound (ticks): one plan step per tick plus
        # one seating/query-chunk tick per request.
        work = sum(
            apb_plan_len(spec["long_chunk_tokens"]) + 1 if r["long"]
            else apb_plan_len(16) + 1
            for r in tr
        )
        print(f"{name:12s} n_long={n_long} classes="
              f"{[r['cls'] for r in tr]} pair_uses={pair_uses} "
              f"hits>={hits} arrivals={ticks} work<={work}")
        if name in ("smoke", "adversarial"):
            assert n_long >= 1, f"{name}: --smoke gate needs a long request"
        if name == "smoke":
            assert hits >= 1, "smoke: --prefix-cache gate needs a warm replay"
            assert work < 1024, (
                f"smoke: admission work bound {work} >= starvation budget — "
                "the starved==0 CI gate could trip")
    print("workload trace mirror OK")


if __name__ == "__main__":
    main()
