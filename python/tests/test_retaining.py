"""Retaining-head compressor: kernel vs oracle, selection invariants, and
the trained-beats-random property that Table 3 relies on."""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import (
    build_features,
    retaining_scores,
    top_lp_select,
)
from compile.kernels import ref
from compile import model as M
from compile.train_retaining import (
    _recall_at,
    make_training_batch,
    snapkv_labels,
    train_retaining_heads,
)

HSETTINGS = dict(max_examples=10, deadline=None,
                 suppress_health_check=list(hypothesis.HealthCheck))


def rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


def test_retaining_scores_match_ref(rng):
    n, kh, hd, r = 37, 2, 8, 16
    feat = rand(rng, n, kh, 3 * hd)
    w1 = rand(rng, 3 * hd, r) * 0.1
    b1 = rand(rng, r) * 0.01
    w2 = rand(rng, r, 1) * 0.1
    b2 = rand(rng, 1) * 0.01
    s = retaining_scores(feat, w1, b1, w2, b2, bn=16)
    rs = ref.retaining_head_ref(feat, w1, b1, w2, b2)
    np.testing.assert_allclose(np.asarray(s), np.asarray(rs), atol=1e-5,
                               rtol=1e-5)


@hypothesis.given(n=st.integers(4, 60), kh=st.sampled_from([1, 2, 3]),
                  seed=st.integers(0, 2**31 - 1))
@hypothesis.settings(**HSETTINGS)
def test_retaining_scores_hypothesis(n, kh, seed):
    rng = np.random.default_rng(seed)
    hd, r = 8, 8
    feat = rand(rng, n, kh, 3 * hd)
    w1 = rand(rng, 3 * hd, r) * 0.2
    b1 = rand(rng, r) * 0.1
    w2 = rand(rng, r, 1) * 0.2
    b2 = rand(rng, 1) * 0.1
    s = retaining_scores(feat, w1, b1, w2, b2, bn=16)
    rs = ref.retaining_head_ref(feat, w1, b1, w2, b2)
    np.testing.assert_allclose(np.asarray(s), np.asarray(rs), atol=2e-5,
                               rtol=2e-5)


def test_build_features_gqa_mean(rng):
    n, h, kh, hd = 6, 4, 2, 8
    q = rand(rng, n, h, hd)
    k = rand(rng, n, kh, hd)
    v = rand(rng, n, kh, hd)
    feat = build_features(q, k, v)
    assert feat.shape == (n, kh, 3 * hd + 2)
    # No query rows -> similarity features are zero.
    assert np.allclose(np.asarray(feat[..., -2:]), 0.0)
    # With query rows the sim features light up on matching keys.
    qq = rand(rng, 3, h, hd)
    feat_q = build_features(q, k, v, q_query=qq)
    assert feat_q.shape == (n, kh, 3 * hd + 2)
    assert not np.allclose(np.asarray(feat_q[..., -2:]), 0.0)
    g = h // kh
    exp_q = np.asarray(q).reshape(n, kh, g, hd).mean(axis=2)
    np.testing.assert_allclose(np.asarray(feat[..., :hd]), exp_q, atol=1e-6)
    np.testing.assert_allclose(np.asarray(feat[..., hd:2 * hd]),
                               np.asarray(k), atol=1e-6)


class TestTopLpSelect:
    def test_selects_argmax_indices_sorted(self, rng):
        n, kh, hd, lp = 20, 2, 4, 5
        scores = rand(rng, n, kh)
        k = rand(rng, n, kh, hd)
        v = rand(rng, n, kh, hd)
        k_c, v_c, idx = top_lp_select(scores, k, v, lp)
        assert k_c.shape == (lp, kh, hd)
        assert idx.shape == (lp, kh)
        s = np.asarray(scores)
        for j in range(kh):
            expect = np.sort(np.argsort(-s[:, j])[:lp])
            np.testing.assert_array_equal(np.asarray(idx[:, j]), expect)

    def test_gathered_rows_match_indices(self, rng):
        n, kh, hd, lp = 16, 2, 4, 4
        scores = rand(rng, n, kh)
        k = rand(rng, n, kh, hd)
        v = rand(rng, n, kh, hd)
        k_c, v_c, idx = top_lp_select(scores, k, v, lp)
        for j in range(kh):
            for t in range(lp):
                i = int(idx[t, j])
                np.testing.assert_allclose(np.asarray(k_c[t, j]),
                                           np.asarray(k[i, j]), atol=1e-6)
                np.testing.assert_allclose(np.asarray(v_c[t, j]),
                                           np.asarray(v[i, j]), atol=1e-6)

    @hypothesis.given(n=st.integers(2, 40), lp_frac=st.floats(0.1, 1.0),
                      seed=st.integers(0, 2**31 - 1))
    @hypothesis.settings(**HSETTINGS)
    def test_invariants(self, n, lp_frac, seed):
        """Exactly l_p indices, in-range, strictly ascending."""
        rng = np.random.default_rng(seed)
        kh, hd = 2, 4
        lp = max(1, int(n * lp_frac))
        scores = rand(rng, n, kh)
        k = rand(rng, n, kh, hd)
        v = rand(rng, n, kh, hd)
        _, _, idx = top_lp_select(scores, k, v, lp)
        ix = np.asarray(idx)
        assert ix.shape == (lp, kh)
        assert (ix >= 0).all() and (ix < n).all()
        for j in range(kh):
            assert (np.diff(ix[:, j]) > 0).all()


def test_random_scores_deterministic():
    a = np.asarray(M.random_scores(1, 2, 3, 8, 2))
    b = np.asarray(M.random_scores(1, 2, 3, 8, 2))
    np.testing.assert_array_equal(a, b)
    c = np.asarray(M.random_scores(1, 2, 4, 8, 2))
    assert not np.array_equal(a, c)


def test_splitmix64_vectors():
    """Pinned vectors — rust util::rng::splitmix64 asserts the same ones."""
    assert M.splitmix64(0) == 0xE220A8397B1DCDAF
    assert M.splitmix64(1) == 0x910A2DEC89025CC1
    assert M.splitmix64(0xDEADBEEF) == 0x4ADFB90F68C9EB9B


def test_training_beats_random(test_cfg, test_params):
    """The trained retaining head must rank true high-attention-mass units
    far better than chance — the R vs Rd. mechanism of Table 3."""
    params = dict(test_params)
    params, hist = train_retaining_heads(params, test_cfg, steps=60,
                                         verbose=False)
    for li, h in hist.items():
        assert h["lossN"] < h["loss0"], f"layer {li} did not train"
        # The pytest backbone is far smaller (d=32) than the artifact
        # configs, so the margin is looser here; the tiny artifact config
        # reaches ~15x random (see aot build logs / EXPERIMENTS.md).
        assert h["recall"] > 2 * h["rand_recall"], (
            f"layer {li}: recall {h['recall']} vs random {h['rand_recall']}")


def test_snapkv_labels_shapes(test_cfg, test_params, rng):
    toks = make_training_batch(test_cfg, np.random.default_rng(0), 64, 8, 1)
    from compile.train_retaining import backbone_qkv
    qkv = backbone_qkv(test_params, test_cfg, toks[0])
    q, k, v, _, _ = qkv[0]
    lab = snapkv_labels(q, k, 8)
    assert lab.shape == (64 - 8, test_cfg.model.n_kv_heads)
    assert np.isfinite(np.asarray(lab)).all()
    assert (np.asarray(lab) >= 0).all()


def test_recall_at_is_one_for_identical():
    lab = np.random.default_rng(0).normal(size=(30, 2)).astype(np.float32)
    assert _recall_at(lab, lab, 7) == 1.0
