import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax  # noqa: E402

jax.config.update("jax_platform_name", "cpu")

from compile.configs import ApbConfig, Config, ModelConfig  # noqa: E402
from compile import model as M  # noqa: E402


@pytest.fixture(scope="session")
def test_cfg() -> Config:
    """Small-but-structured config: GQA groups > 1, several hosts,
    non-trivial anchor/passing lengths."""
    return Config(
        name="pytest",
        model=ModelConfig(vocab_size=64, n_layers=2, d_model=32, n_heads=4,
                          n_kv_heads=2, d_ff=64, retaining_hidden=16),
        apb=ApbConfig(n_hosts=3, block_len=32, anchor_len=8, query_len=4,
                      passing_len=8, max_new_tokens=8),
    )


@pytest.fixture(scope="session")
def test_params(test_cfg):
    return M.init_params(test_cfg)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
