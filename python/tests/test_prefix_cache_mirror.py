"""Python mirror of the shared-prefix KV reuse path
(rust/src/kvcache + coordinator/host.rs, docs/ADR-003-prefix-caching.md),
verifying the prefix-cache bit-identity invariant independently of the
Rust toolchain, for all four attention methods:

* a COLD run prefills the document KV and decodes over the contiguous
  cache (the pre-PR-5 layout);
* a WARM run attaches to the cold run's FROZEN document KV — reused
  verbatim, never recomputed — and decodes over a ``[shared | private
  tail]`` segmented view: the query-chunk rows are appended
  copy-on-extend into per-session tail arrays while the shared arrays
  stay immutable (asserted byte-identical before/after).

The two decodes must agree to Linf <= 4e-15 (they are algebraically the
same key sequence; the Rust suite `rust/tests/prefix_cache.rs` pins exact
f32 equality on the real segmented kernel).

Runs standalone (``python3 test_prefix_cache_mirror.py``, numpy only) or
under pytest alongside the jax-based suite."""
import random

import numpy as np

from test_chunked_prefill_mirror import (
    LAQ, apb_host_tokens, apb_layer_exchange, apb_positions, apb_visible,
    retaining_scores,
)
from test_ring_dense_mirror import (
    DOC_LEN, HD, HOSTS, KH, LB, LQ, VOCAB,
    attn_partial, attn_tail, build_weights, lm_head, masked_attention,
    merge_partials, project_qkv, ring_positions, rope,
)

TOL = 4e-15


# ---------------------------------------------------------------------------
# Prefill -> frozen document KV (what the rust pool's freeze_shared stores)
# ---------------------------------------------------------------------------

def apb_star_caches(embed, layers, doc, query, passing):
    """APB (passing=True) / StarAttn (passing=False) prefill; returns the
    per-host per-layer [k, v] document KV exactly as the slot holds it."""
    hiddens = [embed[apb_host_tokens(doc, query, r)] for r in range(HOSTS)]
    positions = [apb_positions(r) for r in range(HOSTS)]
    caches = [[] for _ in range(HOSTS)]
    for lw in layers:
        pre = []
        for r in range(HOSTS):
            q_nr, k_nr, v = project_qkv(lw, hiddens[r])
            scores = retaining_scores(q_nr[:LQ], q_nr[LAQ:], k_nr[LAQ:])
            q = rope(q_nr, positions[r])
            k = rope(k_nr, positions[r])
            pre.append((q, k, v, scores))
        passes = apb_layer_exchange(pre)
        for r in range(HOSTS):
            q, k, v, _ = pre[r]
            if passing:
                k_pass, v_pass, pass_len = passes[r]
            else:  # StarAttn: blocks never move
                k_pass, v_pass, pass_len = passes[r][0] * 0, passes[r][1] * 0, 0
            n_anchor = LAQ if r > 0 else 0
            k_attn = np.concatenate([k[:LAQ], k_pass, k[LAQ:]])
            v_attn = np.concatenate([v[:LAQ], v_pass, v[LAQ:]])
            att, _ = masked_attention(
                q, k_attn, v_attn,
                lambda qi, kj: apb_visible(n_anchor, pass_len, qi, kj))
            hiddens[r] = attn_tail(lw, hiddens[r], att)
            caches[r].append([k[LAQ:], v[LAQ:]])
    return caches


def ring_caches(embed, layers, doc, query):
    """RingAttn prefill (rotation + merge); per-host per-layer [k, v]."""
    tokens = [query + doc[:LB]] + \
             [doc[r * LB:(r + 1) * LB] for r in range(1, HOSTS)]
    hiddens = [embed[t] for t in tokens]
    positions = [ring_positions(r) for r in range(HOSTS)]
    caches = [[] for _ in range(HOSTS)]
    for lw in layers:
        qkv = []
        for r in range(HOSTS):
            q, k, v = project_qkv(lw, hiddens[r])
            qkv.append((rope(q, positions[r]), rope(k, positions[r]), v))
        for r in range(HOSTS):
            q, k, v = qkv[r]
            outs, lses = [], []
            o, l = attn_partial(lw, q, k, v, positions[r], positions[r])
            outs.append(o)
            lses.append(l)
            for s in range(1, HOSTS):
                origin = (r + HOSTS - s) % HOSTS
                if origin < r:
                    o, l = attn_partial(lw, q, qkv[origin][1], qkv[origin][2],
                                        positions[r], positions[origin])
                    outs.append(o)
                    lses.append(l)
            att = merge_partials(outs, lses)
            hiddens[r] = attn_tail(lw, hiddens[r], att)
            caches[r].append([k, v])
    return caches


def dense_caches(embed, layers, doc, query):
    """Dense prefill: whole [query | doc] on host 0, empty elsewhere."""
    tokens = query + doc
    positions = list(range(len(tokens)))
    hidden = embed[tokens]
    caches = [[] for _ in range(HOSTS)]
    for lw in layers:
        q, k, v = project_qkv(lw, hidden)
        q = rope(q, positions)
        k = rope(k, positions)
        att, _ = attn_partial(lw, q, k, v, positions, positions)
        hidden = attn_tail(lw, hidden, att)
        caches[0].append([k, v])
        for r in range(1, HOSTS):
            caches[r].append([np.zeros((0, KH, HD)), np.zeros((0, KH, HD))])
    return caches


# ---------------------------------------------------------------------------
# Decode: contiguous (cold) vs [shared | tail] segmented (warm attach)
# ---------------------------------------------------------------------------

def decode_contiguous(layers, lmw, embed, caches, query, dense):
    """Cold decode: appended rows concatenate INTO the cache arrays (the
    pre-prefix-cache layout). Mutates `caches` — pass a copy."""
    pos0 = LQ + DOC_LEN
    cpos = list(range(pos0, pos0 + LQ))
    nch = len(cpos)
    last = 0 if dense else HOSTS - 1
    ranks = [0] if dense else range(HOSTS)
    hc = {r: embed[query] for r in ranks}
    for li, lw in enumerate(layers):
        partials = []
        for r in ranks:
            q, k, v = project_qkv(lw, hc[r])
            q = rope(q, cpos)
            k = rope(k, cpos)
            if r == last:
                caches[r][li][0] = np.concatenate([caches[r][li][0], k])
                caches[r][li][1] = np.concatenate([caches[r][li][1], v])
                clen = caches[r][li][0].shape[0]
                o, l = masked_attention(
                    q, caches[r][li][0], caches[r][li][1],
                    lambda qi, kj: kj < clen - (nch - 1 - qi))
            else:
                clen = caches[r][li][0].shape[0]
                o, l = masked_attention(
                    q, caches[r][li][0], caches[r][li][1],
                    lambda qi, kj: kj < clen)
            partials.append((o, l))
        att = merge_partials([p[0] for p in partials],
                             [p[1] for p in partials])
        for r in ranks:
            hc[r] = attn_tail(lw, hc[r], att)
    return lm_head(lmw, hc[last])


def decode_segmented(layers, lmw, embed, shared, query, dense):
    """Warm decode over the ATTACHED shared prefix: `shared` holds the
    frozen document KV (never touched); appended rows go to per-layer TAIL
    arrays copy-on-extend, and attention walks the logical
    [shared | tail] concatenation — the mirror of
    runtime::sim::masked_attention_seg + KvCache::view."""
    pos0 = LQ + DOC_LEN
    cpos = list(range(pos0, pos0 + LQ))
    nch = len(cpos)
    last = 0 if dense else HOSTS - 1
    ranks = [0] if dense else range(HOSTS)
    hc = {r: embed[query] for r in ranks}
    tails = {r: [[np.zeros((0, KH, HD)), np.zeros((0, KH, HD))]
                 for _ in layers] for r in ranks}
    for li, lw in enumerate(layers):
        partials = []
        for r in ranks:
            q, k, v = project_qkv(lw, hc[r])
            q = rope(q, cpos)
            k = rope(k, cpos)
            if r == last:  # copy-on-extend into the PRIVATE tail only
                tails[r][li][0] = np.concatenate([tails[r][li][0], k])
                tails[r][li][1] = np.concatenate([tails[r][li][1], v])
            ck = np.concatenate([shared[r][li][0], tails[r][li][0]])
            cv = np.concatenate([shared[r][li][1], tails[r][li][1]])
            clen = ck.shape[0]
            if r == last:
                o, l = masked_attention(
                    q, ck, cv, lambda qi, kj: kj < clen - (nch - 1 - qi))
            else:
                o, l = masked_attention(q, ck, cv, lambda qi, kj: kj < clen)
            partials.append((o, l))
        att = merge_partials([p[0] for p in partials],
                             [p[1] for p in partials])
        for r in ranks:
            hc[r] = attn_tail(lw, hc[r], att)
    return lm_head(lmw, hc[last])


def deep_copy(caches):
    return [[[kv[0].copy(), kv[1].copy()] for kv in host] for host in caches]


def _request(seed):
    random.seed(seed)
    doc = [random.randrange(1, VOCAB) for _ in range(DOC_LEN)]
    query = [random.randrange(1, VOCAB) for _ in range(LQ)]
    return doc, query


def _check_method(name, caches, lmw, embed, layers, query, dense=False):
    frozen = deep_copy(caches)  # what freeze_shared stores
    cold = decode_contiguous(layers, lmw, embed, deep_copy(caches), query, dense)
    # Warm: attach to the FROZEN arrays — no prefill recomputation at all.
    warm = decode_segmented(layers, lmw, embed, frozen, query, dense)
    d = np.abs(warm - cold).max()
    print(f"{name}: warm-vs-cold logits Linf {d:.3e}")
    assert d <= TOL, f"{name}: segmented warm decode diverged ({d:.3e})"
    assert cold.max() - cold.min() > 0.5, f"{name}: degenerate pipeline"
    # Immutability: the shared entry is byte-identical after serving.
    for r in range(len(frozen)):
        for li in range(len(layers)):
            for c in range(2):
                assert np.array_equal(frozen[r][li][c], caches[r][li][c]), \
                    f"{name}: shared prefix mutated at host {r} layer {li}"


def test_apb_prefix_hit_matches_cold():
    doc, query = _request(41)
    embed, lmw, layers = build_weights()
    caches = apb_star_caches(embed, layers, doc, query, passing=True)
    _check_method("APB", caches, lmw, embed, layers, query)


def test_star_prefix_hit_matches_cold():
    doc, query = _request(43)
    embed, lmw, layers = build_weights()
    caches = apb_star_caches(embed, layers, doc, query, passing=False)
    _check_method("StarAttn", caches, lmw, embed, layers, query)


def test_ring_prefix_hit_matches_cold():
    doc, query = _request(47)
    embed, lmw, layers = build_weights()
    caches = ring_caches(embed, layers, doc, query)
    _check_method("RingAttn", caches, lmw, embed, layers, query)


def test_dense_prefix_hit_matches_cold():
    doc, query = _request(53)
    embed, lmw, layers = build_weights()
    caches = dense_caches(embed, layers, doc, query)
    _check_method("Dense", caches, lmw, embed, layers, query, dense=True)


if __name__ == "__main__":
    test_apb_prefix_hit_matches_cold()
    test_star_prefix_hit_matches_cold()
    test_ring_prefix_hit_matches_cold()
    test_dense_prefix_hit_matches_cold()
    print("OK: prefix-hit (shared | tail) decode is bit-identical to cold")
