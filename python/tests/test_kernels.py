"""L1 kernel vs oracle — the core correctness signal of the compile path.

Every Pallas kernel is pinned against the dense pure-jnp reference in
kernels/ref.py, both on fixed tricky shapes and under hypothesis sweeps of
shapes/dtypes/scalar settings.
"""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import (
    apb_attention,
    causal_attention,
    decode_attention,
)
from compile.kernels import ref

HSETTINGS = dict(max_examples=12, deadline=None,
                 suppress_health_check=list(hypothesis.HealthCheck))


def rand(rng, *shape, dtype=jnp.float32):
    return jnp.asarray(rng.normal(size=shape), dtype)


def assert_close(a, b, tol=2e-5):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=tol,
                               rtol=tol)


# ---------------------------------------------------------------------------
# APB prefill attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_anchor,pass_len", [
    (12, 20), (12, 0), (0, 0), (0, 20), (12, 1), (12, 19),
])
def test_apb_attention_matches_ref(rng, n_anchor, pass_len):
    l_aq, pass_max, l_b, h, kh, hd = 12, 20, 40, 4, 2, 16
    q = rand(rng, l_aq + l_b, h, hd)
    k = rand(rng, l_aq + pass_max + l_b, kh, hd)
    v = rand(rng, l_aq + pass_max + l_b, kh, hd)
    out, lse = apb_attention(q, k, v, n_anchor, pass_len, l_aq=l_aq,
                             pass_max=pass_max, bq=16, bk=16)
    r_out, r_lse = ref.apb_attention_ref(q, k, v, n_anchor, pass_len, l_aq,
                                         pass_max)
    assert_close(out, r_out)
    assert_close(lse, r_lse)


def test_apb_attention_block_size_invariance(rng):
    """Output must not depend on the tile decomposition."""
    l_aq, pass_max, l_b, h, kh, hd = 8, 16, 24, 2, 2, 8
    q = rand(rng, l_aq + l_b, h, hd)
    k = rand(rng, l_aq + pass_max + l_b, kh, hd)
    v = rand(rng, l_aq + pass_max + l_b, kh, hd)
    ref_out, ref_lse = apb_attention(q, k, v, l_aq, 9, l_aq=l_aq,
                                     pass_max=pass_max, bq=8, bk=8)
    for bq, bk in [(16, 8), (8, 32), (32, 16), (128, 128), (7, 13)]:
        out, lse = apb_attention(q, k, v, l_aq, 9, l_aq=l_aq,
                                 pass_max=pass_max, bq=bq, bk=bk)
        assert_close(out, ref_out)
        assert_close(lse, ref_lse)


def test_apb_attention_local_rows_ignore_anchor_when_masked(rng):
    """n_anchor=0 (host 1): local outputs must be independent of the
    anchor K/V contents — the paper's host-1 no-anchor semantics."""
    l_aq, pass_max, l_b, h, kh, hd = 8, 0, 24, 2, 2, 8
    q = rand(rng, l_aq + l_b, h, hd)
    k1 = rand(rng, l_aq + l_b, kh, hd)
    v1 = rand(rng, l_aq + l_b, kh, hd)
    k2 = k1.at[:l_aq].set(999.0)
    v2 = v1.at[:l_aq].set(-999.0)
    out1, _ = apb_attention(q, k1, v1, 0, 0, l_aq=l_aq, pass_max=0, bq=8,
                            bk=8)
    out2, _ = apb_attention(q, k2, v2, 0, 0, l_aq=l_aq, pass_max=0, bq=8,
                            bk=8)
    assert_close(out1[l_aq:], out2[l_aq:])


def test_apb_attention_passing_padding_is_inert(rng):
    """Entries beyond pass_len in the padded passing segment must not
    influence the result."""
    l_aq, pass_max, l_b, h, kh, hd = 8, 16, 16, 2, 2, 8
    nk = l_aq + pass_max + l_b
    q = rand(rng, l_aq + l_b, h, hd)
    k = rand(rng, nk, kh, hd)
    v = rand(rng, nk, kh, hd)
    pass_len = 5
    k_dirty = k.at[l_aq + pass_len:l_aq + pass_max].set(7e3)
    v_dirty = v.at[l_aq + pass_len:l_aq + pass_max].set(-7e3)
    out, lse = apb_attention(q, k, v, l_aq, pass_len, l_aq=l_aq,
                             pass_max=pass_max, bq=8, bk=8)
    out2, lse2 = apb_attention(q, k_dirty, v_dirty, l_aq, pass_len,
                               l_aq=l_aq, pass_max=pass_max, bq=8, bk=8)
    assert_close(out, out2)
    assert_close(lse, lse2)


@hypothesis.given(
    l_aq=st.sampled_from([0, 4, 12]),
    pass_max=st.sampled_from([0, 8, 24]),
    l_b=st.integers(1, 40),
    heads=st.sampled_from([(1, 1), (4, 2), (4, 1), (6, 3)]),
    hd=st.sampled_from([4, 8, 32]),
    seed=st.integers(0, 2**31 - 1),
)
@hypothesis.settings(**HSETTINGS)
def test_apb_attention_hypothesis(l_aq, pass_max, l_b, heads, hd, seed):
    h, kh = heads
    rng = np.random.default_rng(seed)
    n_anchor = rng.choice([0, l_aq])
    pass_len = int(rng.integers(0, pass_max + 1))
    q = rand(rng, l_aq + l_b, h, hd)
    k = rand(rng, l_aq + pass_max + l_b, kh, hd)
    v = rand(rng, l_aq + pass_max + l_b, kh, hd)
    out, lse = apb_attention(q, k, v, n_anchor, pass_len, l_aq=l_aq,
                             pass_max=pass_max, bq=16, bk=16)
    r_out, r_lse = ref.apb_attention_ref(q, k, v, n_anchor, pass_len, l_aq,
                                         pass_max)
    assert_close(out, r_out, tol=5e-5)
    assert_close(lse, r_lse, tol=5e-5)


@hypothesis.given(
    dtype=st.sampled_from(["float32", "bfloat16"]),
    l_b=st.sampled_from([8, 33]),
    seed=st.integers(0, 2**31 - 1),
)
@hypothesis.settings(**HSETTINGS)
def test_apb_attention_dtypes(dtype, l_b, seed):
    """bf16 inputs accumulate in f32; tolerance scaled to bf16 ulp."""
    rng = np.random.default_rng(seed)
    l_aq, pass_max, h, kh, hd = 4, 8, 2, 2, 8
    dt = jnp.dtype(dtype)
    q = rand(rng, l_aq + l_b, h, hd, dtype=dt)
    k = rand(rng, l_aq + pass_max + l_b, kh, hd, dtype=dt)
    v = rand(rng, l_aq + pass_max + l_b, kh, hd, dtype=dt)
    out, _ = apb_attention(q, k, v, l_aq, 3, l_aq=l_aq, pass_max=pass_max,
                           bq=16, bk=16)
    r_out, _ = ref.apb_attention_ref(q, k, v, l_aq, 3, l_aq, pass_max)
    tol = 2e-5 if dtype == "float32" else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(r_out, np.float32),
                               atol=tol, rtol=tol)


# ---------------------------------------------------------------------------
# Causal (FLASHATTN baseline) mode
# ---------------------------------------------------------------------------

def test_causal_attention_matches_dense(rng):
    n, h, kh, hd = 50, 4, 2, 16
    q = rand(rng, n, h, hd)
    k = rand(rng, n, kh, hd)
    v = rand(rng, n, kh, hd)
    out, lse = causal_attention(q, k, v, bq=16, bk=16)
    r_out, r_lse = ref.attention_ref(q, k, v, ref.causal_mask(n))
    assert_close(out, r_out)
    assert_close(lse, r_lse)


def test_causal_first_row_attends_self_only(rng):
    n, h, hd = 8, 2, 8
    q = rand(rng, n, h, hd)
    k = rand(rng, n, h, hd)
    v = rand(rng, n, h, hd)
    out, _ = causal_attention(q, k, v, bq=8, bk=8)
    assert_close(out[0], np.asarray(v[0]))


# ---------------------------------------------------------------------------
# Decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,cache_len,self_causal", [
    (1, 17, 0), (1, 18, 1), (5, 40, 0), (5, 45, 1), (5, 5, 1), (1, 1, 1),
])
def test_decode_attention_matches_ref(rng, n, cache_len, self_causal):
    cmax, h, kh, hd = 48, 4, 2, 16
    q = rand(rng, n, h, hd)
    kc = rand(rng, cmax, kh, hd)
    vc = rand(rng, cmax, kh, hd)
    out, lse = decode_attention(q, kc, vc, cache_len, self_causal, bq=8,
                                bk=16)
    r_out, r_lse = ref.decode_attention_ref(q, kc, vc, cache_len,
                                            self_causal)
    assert_close(out, r_out)
    assert_close(lse, r_lse)


def test_decode_attention_padding_is_inert(rng):
    cmax, n, h, kh, hd = 32, 3, 2, 2, 8
    q = rand(rng, n, h, hd)
    kc = rand(rng, cmax, kh, hd)
    vc = rand(rng, cmax, kh, hd)
    cl = 11
    kc2 = kc.at[cl:].set(1e4)
    vc2 = vc.at[cl:].set(-1e4)
    out, _ = decode_attention(q, kc, vc, cl, 0, bq=8, bk=8)
    out2, _ = decode_attention(q, kc2, vc2, cl, 0, bq=8, bk=8)
    assert_close(out, out2)


@hypothesis.given(
    n=st.integers(1, 9),
    kh=st.sampled_from([1, 2]),
    g=st.sampled_from([1, 2]),
    self_causal=st.sampled_from([0, 1]),
    seed=st.integers(0, 2**31 - 1),
)
@hypothesis.settings(**HSETTINGS)
def test_decode_attention_hypothesis(n, kh, g, self_causal, seed):
    rng = np.random.default_rng(seed)
    cmax, hd = 40, 8
    h = kh * g
    lo = n if self_causal else 1
    cache_len = int(rng.integers(lo, cmax + 1))
    q = rand(rng, n, h, hd)
    kc = rand(rng, cmax, kh, hd)
    vc = rand(rng, cmax, kh, hd)
    out, lse = decode_attention(q, kc, vc, cache_len, self_causal, bq=8,
                                bk=16)
    r_out, r_lse = ref.decode_attention_ref(q, kc, vc, cache_len,
                                            self_causal)
    assert_close(out, r_out, tol=5e-5)
    assert_close(lse, r_lse, tol=5e-5)


# ---------------------------------------------------------------------------
# Distributed LSE merge (Algorithm 3)
# ---------------------------------------------------------------------------

@hypothesis.given(
    hosts=st.integers(1, 5),
    n=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
)
@hypothesis.settings(**HSETTINGS)
def test_merge_partials_equals_global_softmax(hosts, n, seed):
    """Splitting keys across hosts, computing per-host partials + LSE and
    merging must equal single-host attention over all keys."""
    rng = np.random.default_rng(seed)
    h, kh, hd = 2, 2, 8
    lens = rng.integers(1, 12, size=hosts)
    q = rand(rng, n, h, hd)
    ks = [rand(rng, int(l), kh, hd) for l in lens]
    vs = [rand(rng, int(l), kh, hd) for l in lens]
    outs, lses = [], []
    for kpart, vpart in zip(ks, vs):
        full = jnp.ones((n, kpart.shape[0]), bool)
        o, s = ref.attention_ref(q, kpart, vpart, full)
        outs.append(o)
        lses.append(s)
    merged, mlse = ref.merge_partials_ref(outs, lses)
    k_all = jnp.concatenate(ks)
    v_all = jnp.concatenate(vs)
    o_all, lse_all = ref.attention_ref(
        q, k_all, v_all, jnp.ones((n, k_all.shape[0]), bool))
    assert_close(merged, o_all, tol=5e-5)
    assert_close(mlse, lse_all, tol=5e-5)


def test_merge_partials_handles_empty_host():
    """A host whose partial saw zero keys (lse=-inf) must not corrupt the
    merge."""
    n, h, hd = 2, 2, 4
    rng = np.random.default_rng(3)
    o1 = rand(rng, n, h, hd)
    l1 = jnp.zeros((n, h))
    o2 = jnp.zeros((n, h, hd))
    l2 = jnp.full((n, h), -np.inf)
    merged, mlse = ref.merge_partials_ref([o1, o2], [l1, l2])
    assert_close(merged, o1)
    assert_close(mlse, l1)
