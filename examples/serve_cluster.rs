//! End-to-end serving driver (DESIGN.md deliverable): serve a queue of
//! overlapping long-context requests through the continuous-batching
//! scheduler — several sessions' KV resident on the cluster at once, one
//! stacked decode pass per layer per step — and report latency/throughput
//! percentiles including TTFT/TPOT.
//!
//!     cargo run --release --example serve_cluster -- --requests 6 \
//!         --config tiny --max-new 6
//!
//! With `--prefix-cache` the workload becomes the multi-tenant
//! shared-corpus pattern instead: every request queries ONE document, the
//! first admission freezes its KV into the pool's shared-prefix store, and
//! each later request attaches warm (no document pass at all) — the demo
//! prints the cold-vs-warm TTFT split (`docs/ADR-003-prefix-caching.md`).
//!
//! Results land in the committed bench artifacts (`BENCH_serving.json`,
//! `BENCH_decode.json`; see README "Bench artifacts").

use apb::bench_harness::Table;
use apb::config::{ApbOptions, AttnMethod};
use apb::coordinator::scheduler::{Request, Scheduler};
use apb::coordinator::{Cluster, Driver};
use apb::ruler::{gen_instance, TaskKind};
use apb::util::cli::Args;
use apb::util::rng::Rng;
use apb::util::stats::{fmt_duration, fmt_rate};

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1), &["smoke", "prefix-cache"])?;
    args.check_known(&[
        "requests", "config", "max-new", "queue", "seed", "method", "chunk-tokens",
        "driver",
    ])?;
    let n_requests = args.usize_or("requests", 6)?;
    let max_new = args.usize_or("max-new", 6)?;
    let config = args.str_or("config", "tiny");
    let seed = args.usize_or("seed", 7)? as u64;
    let method = AttnMethod::parse(&args.str_or("method", "apb"))?;
    let prefix_cache = args.has("prefix-cache");
    let driver = match args.get("driver") {
        Some(s) => Driver::parse(s).ok_or_else(|| anyhow::anyhow!(
            "--driver={s} is not a driver (expected sequential|threaded)"))?,
        None => Driver::from_env(),
    };

    let mut cfg = apb::load_config_or_sim(&config)?
        .with_method(method)
        .with_prefix_cache(prefix_cache);
    cfg.apb.chunk_tokens = args.usize_or("chunk-tokens", cfg.apb.chunk_tokens)?.max(1);
    println!(
        "serving on {} hosts ({} backend) — model d={} L={} vocab={}, doc {} \
         tokens/request, up to {} sessions resident",
        cfg.apb.n_hosts, cfg.backend.name(), cfg.model.d_model, cfg.model.n_layers,
        cfg.model.vocab_size, cfg.apb.doc_len(), cfg.apb.max_resident
    );
    let t_start = std::time::Instant::now();
    let cluster = Cluster::start_with(&cfg, driver)?;
    println!("cluster up in {:.1}s (compile + weight upload per host, {} driver)",
             t_start.elapsed().as_secs_f64(), cluster.driver().name());

    let mut scheduler = Scheduler::new(&cluster, args.usize_or("queue", 64)?);
    let mut rng = Rng::new(seed);
    let opts = ApbOptions { method, ..Default::default() };
    let t0 = std::time::Instant::now();
    let done = if prefix_cache {
        // Shared-corpus workload: one document, many queriers. Sequential
        // (submit + drain per request) so each warm TTFT measures service
        // time, not queueing behind the cold miss's prefill.
        let inst = gen_instance(&cfg, TaskKind::SingleNiah, &mut rng);
        println!("shared corpus: {} requests over one {}-token document",
                 n_requests, inst.doc.len());
        for id in 0..n_requests {
            scheduler.submit(Request {
                id: id as u64,
                doc: inst.doc.clone(),
                query: inst.query.clone(),
                max_new,
                opts,
                class: Default::default(),
            })?;
            scheduler.run_all()?;
        }
        scheduler.completed.len()
    } else {
        // Queue a mixed workload of retrieval-style long-context requests.
        let kinds = [
            TaskKind::SingleNiah,
            TaskKind::MultiKeyNiah { keys: 3 },
            TaskKind::MultiValueNiah,
            TaskKind::Aggregation,
        ];
        for id in 0..n_requests {
            let inst = gen_instance(&cfg, kinds[id % kinds.len()], &mut rng);
            scheduler.submit(Request {
                id: id as u64,
                doc: inst.doc,
                query: inst.query,
                max_new,
                opts,
                class: Default::default(),
            })?;
        }
        println!("queued {} requests", scheduler.queued());
        scheduler.run_all()?
    };
    let wall = t0.elapsed().as_secs_f64();
    let m = scheduler.metrics();

    let mut table = Table::new("serving metrics", &["metric", "value"]);
    table.row(vec!["requests served".into(), done.to_string()]);
    table.row(vec!["wall time".into(), fmt_duration(wall)]);
    table.row(vec!["request throughput".into(),
                   format!("{:.2} req/s", done as f64 / wall)]);
    table.row(vec!["token throughput (in+out)".into(), fmt_rate(
        (done * (cfg.apb.doc_len() + cfg.apb.query_len + max_new)) as f64 / wall)]);
    table.row(vec!["peak resident sessions".into(), m.peak_resident.to_string()]);
    table.row(vec!["prefill chunk steps (mean)".into(),
                   format!("{:.0}", m.prefill_chunks.mean)]);
    table.row(vec!["prefill p50 / p99".into(),
                   format!("{} / {}", fmt_duration(m.prefill.p50),
                           fmt_duration(m.prefill.p99))]);
    table.row(vec!["ttft p50 / p99".into(),
                   format!("{} / {}", fmt_duration(m.ttft.p50),
                           fmt_duration(m.ttft.p99))]);
    table.row(vec!["tpot p50 / p99".into(),
                   format!("{} / {}", fmt_duration(m.tpot.p50),
                           fmt_duration(m.tpot.p99))]);
    table.row(vec!["decode p50 / p99".into(),
                   format!("{} / {}", fmt_duration(m.decode.p50),
                           fmt_duration(m.decode.p99))]);
    table.row(vec!["e2e p50 / p99".into(),
                   format!("{} / {}", fmt_duration(m.e2e.p50),
                           fmt_duration(m.e2e.p99))]);
    table.row(vec!["queue wait p50".into(), fmt_duration(m.queue_wait.p50)]);
    table.row(vec!["decode comm".into(), format!("{} B", m.decode_comm_bytes)]);
    table.row(vec!["paper speed metric (mean)".into(),
                   format!("{:.0} tok/s", m.speed_tok_per_s.mean)]);
    if prefix_cache {
        table.row(vec!["prefix hits".into(),
                       format!("{} / {}", m.prefix_hits, m.n_requests)]);
        table.row(vec!["prefix KV bytes saved".into(),
                       format!("{} B", m.prefix_bytes_saved)]);
        let fmt = |s: &Option<apb::util::stats::Summary>| {
            s.as_ref().map_or("-".to_string(), |s| fmt_duration(s.p50))
        };
        table.row(vec!["ttft p50 cold / warm".into(),
                       format!("{} / {}", fmt(&m.ttft_cold), fmt(&m.ttft_warm))]);
    }
    table.print();

    for r in &scheduler.completed {
        println!("  req {:>2}: tokens {:?}  ttft {}{}  speed {:.0} tok/s", r.id,
                 r.tokens, fmt_duration(r.ttft_s),
                 if r.prefill.prefix_hit { " (warm)" } else { "" },
                 r.speed_tok_per_s);
    }
    if args.has("smoke") {
        // CI gate: the continuous-batching path must actually overlap
        // sessions when more than one request is queued.
        assert_eq!(done, n_requests, "all requests must complete");
        if !prefix_cache && n_requests >= 2 && cfg.apb.max_resident >= 2 {
            assert!(m.peak_resident >= 2,
                    "smoke: expected >= 2 sessions resident, saw {}",
                    m.peak_resident);
        }
        if prefix_cache && n_requests >= 2 {
            assert_eq!(m.prefix_hits, n_requests - 1,
                       "every request after the cold miss must hit");
            assert!(m.prefix_bytes_saved > 0, "hits must save KV bytes");
            // Best warm sample vs the cold miss: robust to a one-off OS
            // hiccup on a loaded runner (see `apb serve --smoke`).
            let cold = m.ttft_cold.expect("cold sample").min;
            let warm = m.ttft_warm.expect("warm samples").min;
            assert!(warm < cold, "best warm TTFT must beat the cold miss");
        }
        println!("serve_cluster --smoke OK");
    }
    Ok(())
}
