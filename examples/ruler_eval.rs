//! Real-measurement RULER-style evaluation on the tiny PJRT cluster:
//! for each task kind, measure the *mechanisms* the paper's accuracy
//! story rests on —
//!
//!   * retention recall: do the trained retaining heads keep the needle
//!     KV units in the top-l_p passing block? (vs the random selector)
//!   * approximation divergence: L∞ logit distance of each method-mode
//!     from the full-APB computation;
//!   * communication volume per mode.
//!
//! Absolute task accuracy needs a pretrained LLM (substituted per
//! DESIGN.md §2); these measured mechanism numbers are what the oracle's
//! parameters are sanity-checked against.

use apb::bench_harness::Table;
use apb::config::ApbOptions;
use apb::coordinator::Cluster;
use apb::ruler::{gen_instance, TaskKind};
use apb::util::cli::Args;
use apb::util::rng::Rng;

fn linf(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1), &[])?;
    args.check_known(&["samples", "config", "seed"])?;
    let samples = args.usize_or("samples", 3)?;
    let cfg = apb::load_config_or_sim(&args.str_or("config", "tiny"))?;
    let cluster = Cluster::start(&cfg)?;

    let kinds: [(&str, TaskKind); 4] = [
        ("SG (single needle)", TaskKind::SingleNiah),
        ("MK (multi-key)", TaskKind::MultiKeyNiah { keys: 3 }),
        ("MV (multi-value)", TaskKind::MultiValueNiah),
        ("AG (aggregation)", TaskKind::Aggregation),
    ];

    let mut table = Table::new(
        "measured mechanisms (tiny cluster, real PJRT numerics)",
        &["task", "recall(R)", "recall(Rd.)", "Linf no-pass", "Linf Rd.",
          "Linf no-anchor", "comm KB"],
    );
    let mut rng = Rng::new(args.usize_or("seed", 11)? as u64);
    let mut avg_r = 0.0;
    let mut avg_rd = 0.0;
    for (name, kind) in kinds {
        let mut recall_r = 0.0;
        let mut recall_rd = 0.0;
        let mut d_nopass = 0.0f32;
        let mut d_rd = 0.0f32;
        let mut d_noanchor = 0.0f32;
        let mut comm = 0u64;
        for _ in 0..samples {
            let inst = gen_instance(&cfg, kind, &mut rng);
            // Full APB (recall experiments opt in to the retained record).
            cluster.clear()?;
            let recorded = ApbOptions { record_retained: true, ..Default::default() };
            let rep = cluster.prefill(&inst.doc, &inst.query, &recorded)?;
            let base = cluster.generate(&inst.query, 1)?.query_logits;
            recall_r += rep.retention_recall(&cfg, &inst.needle_positions);
            comm += rep.comm_bytes;
            // Random selector.
            cluster.clear()?;
            let rep_rd = cluster.prefill(
                &inst.doc, &inst.query,
                &ApbOptions { retaining_compressor: false, ..recorded })?;
            let g_rd = cluster.generate(&inst.query, 1)?.query_logits;
            recall_rd += rep_rd.retention_recall(&cfg, &inst.needle_positions);
            d_rd = d_rd.max(linf(&g_rd, &base));
            // No passing (Star-mode).
            cluster.clear()?;
            cluster.prefill(
                &inst.doc,
                &inst.query,
                &ApbOptions {
                    method: apb::config::AttnMethod::StarAttn,
                    ..Default::default()
                },
            )?;
            let g_np = cluster.generate(&inst.query, 1)?.query_logits;
            d_nopass = d_nopass.max(linf(&g_np, &base));
            // No anchor.
            cluster.clear()?;
            cluster.prefill(&inst.doc, &inst.query,
                            &ApbOptions { use_anchor: false, ..Default::default() })?;
            let g_na = cluster.generate(&inst.query, 1)?.query_logits;
            d_noanchor = d_noanchor.max(linf(&g_na, &base));
        }
        let s = samples as f64;
        avg_r += recall_r / s;
        avg_rd += recall_rd / s;
        table.row(vec![
            name.into(),
            format!("{:.3}", recall_r / s),
            format!("{:.3}", recall_rd / s),
            format!("{d_nopass:.3}"),
            format!("{d_rd:.3}"),
            format!("{d_noanchor:.3}"),
            format!("{:.1}", comm as f64 / s / 1024.0),
        ]);
    }
    table.print();
    let k = kinds.len() as f64;
    println!("\nmean retention recall: trained {:.3} vs random {:.3} \
              (expected random ≈ l_p/l_b = {:.3})",
             avg_r / k, avg_rd / k,
             cfg.apb.passing_len as f64 / cfg.apb.block_len as f64);
    println!("The trained-vs-random gap is the measured counterpart of the \
              R vs Rd. ablation (paper Table 3).");
    Ok(())
}
