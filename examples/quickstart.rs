//! Quickstart: start an APB cluster, prefill one long document, and
//! generate greedily.
//!
//!     cargo run --release --example quickstart
//!
//! runs out of the box on the native SimEngine backend (no artifacts).
//! With `make artifacts` + `--features pjrt` the same code replays the
//! AOT'd HLO artifacts instead. Python never runs on the request path.

use apb::config::ApbOptions;
use apb::coordinator::Cluster;
use apb::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // 1. Load the artifact config when present, else the sim-tiny config.
    let cfg = apb::load_config_or_sim("tiny")?;
    println!(
        "config '{}' ({} backend): {} hosts × block {} (anchor {}, query {}, \
         passing {}), model d={} L={}",
        cfg.name, cfg.backend.name(), cfg.apb.n_hosts, cfg.apb.block_len,
        cfg.apb.anchor_len, cfg.apb.query_len, cfg.apb.passing_len,
        cfg.model.d_model, cfg.model.n_layers
    );

    // 2. Spawn the cluster: one worker thread per host, each owning its
    //    execution backend (native SimEngine, or a PJRT engine that
    //    compiles the AOT artifacts and uploads weights once).
    let cluster = Cluster::start(&cfg)?;

    // 3. Build a request: a document split across hosts plus a query.
    let mut rng = Rng::new(42);
    let doc: Vec<i32> = (0..cfg.apb.doc_len())
        .map(|_| rng.range(1, cfg.model.vocab_size as i64) as i32)
        .collect();
    let query: Vec<i32> = (0..cfg.apb.query_len)
        .map(|_| rng.range(1, cfg.model.vocab_size as i64) as i32)
        .collect();

    // 4. APB prefill (Algorithm 2): per-layer compression + AllGather of
    //    compressed context blocks + modified-mask attention.
    let report = cluster.prefill(&doc, &query, &ApbOptions::default())?;
    println!(
        "prefill: {:.1} ms wall, {} bytes of compressed KV exchanged",
        report.wall_seconds * 1e3,
        report.comm_bytes
    );

    // 5. Distributed decode (Algorithm 3): query chunk + greedy tokens via
    //    per-host partial attention and online-softmax merge.
    let gen = cluster.generate(&query, 8)?;
    println!("generated tokens: {:?}", gen.tokens);
    println!(
        "decode: {:.1} ms ({:.1} ms/token)",
        gen.wall_seconds * 1e3,
        gen.wall_seconds * 1e3 / gen.tokens.len() as f64
    );

    // 6. The paper's speed metric.
    let speed = (doc.len() + query.len() + gen.tokens.len()) as f64
        / (report.wall_seconds + gen.wall_seconds);
    println!("speed = (in+out)/(prefill+decode) = {speed:.0} tok/s");
    Ok(())
}
