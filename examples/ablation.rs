//! Real-cluster ablation driver (Table 3's measured twin): run every
//! combination of the four APB components on one fixed request and report
//! how each changes the computation, the communication, and the
//! compressor's needle retention.
//!
//!     cargo run --release --example ablation -- --max-new 4

use apb::bench_harness::Table;
use apb::config::{ApbOptions, AttnMethod};
use apb::coordinator::Cluster;
use apb::ruler::{gen_instance, TaskKind};
use apb::util::cli::Args;
use apb::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1), &[])?;
    args.check_known(&["config", "max-new", "seed"])?;
    let cfg = apb::load_config_or_sim(&args.str_or("config", "tiny"))?;
    let max_new = args.usize_or("max-new", 4)?;
    let cluster = Cluster::start(&cfg)?;

    let mut rng = Rng::new(args.usize_or("seed", 21)? as u64);
    let inst = gen_instance(&cfg, TaskKind::MultiKeyNiah { keys: 3 }, &mut rng);

    // Baseline: full APB.
    cluster.clear()?;
    let recorded = ApbOptions { record_retained: true, ..Default::default() };
    let base_rep = cluster.prefill(&inst.doc, &inst.query, &recorded)?;
    let base = cluster.generate(&inst.query, max_new)?;
    println!("baseline tokens: {:?}  (recall {:.3}, comm {} B)",
             base.tokens,
             base_rep.retention_recall(&cfg, &inst.needle_positions),
             base_rep.comm_bytes);

    let mut table = Table::new(
        "APB component ablations (measured on the tiny cluster)",
        &["A", "P", "C", "Q", "tokens==base", "logit Linf", "recall", "comm B",
          "prefill ms"],
    );
    for bits in 0..16u32 {
        let o = ApbOptions {
            use_anchor: bits & 8 != 0,
            // "P" bit: passing on = APB, passing off = StarAttn.
            method: if bits & 4 != 0 {
                AttnMethod::Apb
            } else {
                AttnMethod::StarAttn
            },
            retaining_compressor: bits & 2 != 0,
            embed_query: bits & 1 != 0,
            record_retained: true,
            ..Default::default()
        };
        cluster.clear()?;
        let rep = cluster.prefill(&inst.doc, &inst.query, &o)?;
        let gen = cluster.generate(&inst.query, max_new)?;
        let linf = gen
            .query_logits
            .iter()
            .zip(&base.query_logits)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        let yn = |b: bool| if b { "Y" } else { "x" };
        table.row(vec![
            yn(o.use_anchor).into(),
            yn(o.method.passes_compressed_blocks()).into(),
            if o.retaining_compressor { "R" } else { "Rd." }.into(),
            yn(o.embed_query).into(),
            (gen.tokens == base.tokens).to_string(),
            format!("{linf:.4}"),
            format!("{:.3}", rep.retention_recall(&cfg, &inst.needle_positions)),
            rep.comm_bytes.to_string(),
            format!("{:.0}", rep.wall_seconds * 1e3),
        ]);
    }
    table.print();
    println!("\nReading guide: removing P zeroes comm; removing C (R->Rd.) \
              collapses recall to ~l_p/l_b; removing A perturbs logits the \
              most (the paper's catastrophic rows 6-8 in Table 3).");
    Ok(())
}
