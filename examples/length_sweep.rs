//! Length-sweep explorer over the analytical model: prefill time, speed,
//! FLOPs and memory for any method/host-count/length grid — the
//! interactive companion to Figures 1 and 4.
//!
//!     cargo run --release --example length_sweep -- \
//!         --hosts 4,8,16 --lengths 32768,131072,524288 --model llama

use apb::attnsim::{estimate, speed_tok_per_s, Hyper, Method, A800, LLAMA31_8B,
                   QWEN25_14B, YI_34B};
use apb::bench_harness::Table;
use apb::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1), &[])?;
    args.check_known(&["hosts", "lengths", "model", "out"])?;
    let hosts = args.usize_list_or("hosts", &[8])?;
    let lengths = args.usize_list_or(
        "lengths", &[32768, 65536, 131072, 262144, 524288, 1048576])?;
    let model = match args.str_or("model", "llama").as_str() {
        "llama" => LLAMA31_8B,
        "qwen" => QWEN25_14B,
        "yi" => YI_34B,
        other => anyhow::bail!("unknown model '{other}' (llama|qwen|yi)"),
    };
    let n_out = args.usize_or("out", 64)? as f64;

    for &h in &hosts {
        let mut table = Table::new(
            &format!("{} on {h} hosts — prefill s / speed tok/s / PFLOPs / peak GB",
                     model.name),
            &["Method", "n", "prefill", "speed", "PFLOPs", "mem GB"],
        );
        for method in Method::ALL {
            let hm = if method.uses_sequence_parallelism() { h as f64 } else { 1.0 };
            for &n in &lengths {
                let n = n as f64;
                let hy = Hyper::paper_schedule(n, h as f64);
                let est = estimate(method, &model, n, hm, &hy, &A800, n_out);
                let (pre, spd) = if est.oom {
                    ("OOM".to_string(), "-".to_string())
                } else {
                    (format!("{:.2}", est.prefill_s),
                     format!("{:.0}", speed_tok_per_s(&est, n, n_out).unwrap()))
                };
                table.row(vec![
                    method.name().into(),
                    format!("{}K", n as usize / 1024),
                    pre,
                    spd,
                    format!("{:.1}", est.flops_total / 1e15),
                    format!("{:.0}", est.mem_bytes_peak / 1e9),
                ]);
            }
        }
        table.print();
    }
    Ok(())
}
